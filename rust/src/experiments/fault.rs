//! `repro --fig fault` — the fault-tolerant fleet day: §3.4 recovery
//! driven by the *live* fleet loop (Fig. 13c against serving traffic)
//! plus the cross-scene instance-lending ledger.
//!
//! Three claims, asserted at tier-1:
//!
//! 1. **Recovery shape**: every recovery the day produces follows the
//!    Fig. 13c phase order — detection → logical removal → protection →
//!    RoCE join → model load → health → erase — and its outage is
//!    dominated by the model load.
//! 2. **Bounded degradation**: under an accelerated fault rate (the
//!    paper's 1.5/week/400-devices knob scaled so a small simulated
//!    fleet sees the fault pressure of tens of thousands of NPUs), E2E
//!    completions over a *paired* day (identical arrivals) stay within
//!    [`FAULT_TPUT_BOUND`] of the fault-free day.
//! 3. **Lending discipline**: on a phased two-scene day with lending on,
//!    at least one cross-scene lease is granted, the instance books
//!    balance, and every lease is repaid before the lender's own peak
//!    (leases maturing past the end of the day may remain outstanding).

use crate::coordinator::mlops::LeaseUse;
use crate::coordinator::recovery::phases_ordered;
use crate::serving::fleet::{FleetConfig, FleetOutput, FleetSim};
use crate::workload::traffic::{diurnal_factor, scene_phase};

use super::Scale;

/// Stated bound: completions under faults ≥ this fraction of fault-free.
pub const FAULT_TPUT_BOUND: f64 = 0.75;

/// Due-hours this close to the end of the day cannot be enforced inside
/// it (the lease call + drain needs lead time); later dues are exempt
/// from the repaid-in-day assertion.
pub const LEASE_ENFORCE_MARGIN_H: f64 = 2.0;

/// The paired fault/fault-free comparison plus the lending day.
pub struct FaultRepro {
    /// Fault-free day (paired arrivals with `faulty`).
    pub clean: FleetOutput,
    /// Same day under the accelerated fault rate.
    pub faulty: FleetOutput,
    /// Phased two-scene lending day (`--lend`).
    pub lend: FleetOutput,
}

impl FaultRepro {
    /// Completions under faults as a fraction of the fault-free day.
    pub fn completion_ratio(&self) -> f64 {
        if self.clean.completed == 0 {
            1.0
        } else {
            self.faulty.completed as f64 / self.clean.completed as f64
        }
    }
}

/// The paired day: two scenes, two static groups each (capacity loop off
/// so the comparison isolates the fault path), identical arrival streams.
fn paired_cfg(scale: Scale, faults: bool) -> FleetConfig {
    let fast = scale.closed_requests < Scale::full().closed_requests;
    FleetConfig {
        scenes: vec![2, 5],
        min_groups_per_scene: 2,
        max_groups_per_scene: 3,
        scale_groups: false,
        peak_total_rps: 24.0,
        hours: 24.0,
        ms_per_hour: if fast { 1_500.0 } else { 3_000.0 },
        control_period_ms: 1_500.0,
        slice_ms: 500.0,
        // ~4 groups × 6 instances × 8 devices = 192 devices; 300/week/400
        // ⇒ ~20 faults over the day, ~8 fatal — the fault pressure a
        // 40 000-NPU fleet sees at the paper's observed 1.5 rate.
        faults_per_week: if faults { 300.0 } else { 0.0 },
        seed: 0xFA17,
        ..Default::default()
    }
}

/// The lending day: two scenes with opposed diurnal phases (scene 0
/// peaks in the lender's work day, scene 2 six hours later), lending on,
/// one group's worth of spares. The early scene scales out of the pool,
/// banks its groups across its decline, and the late scene's ramp can
/// only be funded by borrowing against that bank. 30 hours so the last
/// borrower trough (and with it the repayment) falls inside the run.
fn lending_cfg(scale: Scale) -> FleetConfig {
    let fast = scale.closed_requests < Scale::full().closed_requests;
    FleetConfig {
        scenes: vec![0, 2],
        min_groups_per_scene: 1,
        max_groups_per_scene: 3,
        scale_groups: true,
        lend: true,
        spare_instances: 6,
        peak_total_rps: 60.0,
        hours: 30.0,
        ms_per_hour: if fast { 1_500.0 } else { 3_000.0 },
        control_period_ms: 1_500.0,
        slice_ms: 500.0,
        faults_per_week: 0.0,
        seed: 0x1E4D,
        ..Default::default()
    }
}

/// The lender's first diurnal peak after a lease is granted.
pub fn lender_peak_hour(lender: usize, granted_hour: f64) -> f64 {
    let phase = scene_phase(lender);
    let mut best = (granted_hour, f64::MIN);
    let mut h = granted_hour + 0.25;
    while h <= granted_hour + 24.0 {
        let f = diurnal_factor(h, phase);
        if f > best.1 {
            best = (h, f);
        }
        h += 0.25;
    }
    best.0
}

/// The paired comparison alone: (fault-free day, faulted day).
pub fn paired_days(scale: Scale) -> (FleetOutput, FleetOutput) {
    let clean = FleetSim::new(paired_cfg(scale, false)).run();
    let faulty = FleetSim::new(paired_cfg(scale, true)).run();
    (clean, faulty)
}

/// The lending day alone.
pub fn lending_day(scale: Scale) -> FleetOutput {
    FleetSim::new(lending_cfg(scale)).run()
}

/// Run all three days.
pub fn fault_repro(scale: Scale) -> FaultRepro {
    let (clean, faulty) = paired_days(scale);
    FaultRepro { clean, faulty, lend: lending_day(scale) }
}

pub fn run(scale: Scale, json_dir: Option<&str>) {
    let r = fault_repro(scale);
    let rows = vec![
        (
            "fault-free day".to_string(),
            format!(
                "{} completed, {:.2} rps, {:.0}% SLO",
                r.clean.completed,
                r.clean.rps,
                r.clean.slo_attainment * 100.0
            ),
        ),
        (
            format!("{} fatal faults", r.faulty.faults_fatal),
            format!(
                "{} completed, {:.2} rps, {:.0}% SLO, {} protected",
                r.faulty.completed,
                r.faulty.rps,
                r.faulty.slo_attainment * 100.0,
                r.faulty.protected
            ),
        ),
    ];
    super::table(
        "Fig fault — paired fleet day under the paper's fault regime (§3.4)",
        ("day", "E2E outcome"),
        &rows,
    );
    println!(
        "completions under faults: {:.1}% of fault-free (stated bound {:.0}%); \
         {} faults drawn, {} fatal, {} recoveries",
        r.completion_ratio() * 100.0,
        FAULT_TPUT_BOUND * 100.0,
        r.faulty.faults_seen,
        r.faulty.faults_fatal,
        r.faulty.recoveries
    );
    if let Some((hour, rep)) = r.faulty.recovery_reports.first() {
        println!(
            "\nfirst recovery ({:.2} h, instance {} -> container {}, {} protected):",
            hour, rep.failed_instance, rep.substitute_instance, rep.protected_requests
        );
        print!("{}", rep.trace.render());
    }
    println!("\nlending day (phased scenes 0/2, {} leases):", r.lend.ledger.leases.len());
    r.lend.print_summary(false);
    for lease in &r.lend.ledger.leases {
        if let LeaseUse::Scene(_) = lease.borrower {
            let peak = lender_peak_hour(lease.lender, lease.granted_hour);
            println!(
                "  lease #{}: lender scene {} peaks at {:.2} h, repaid {}",
                lease.id,
                lease.lender,
                peak,
                lease
                    .repaid_hour
                    .map(|h| format!("{h:.2} h"))
                    .unwrap_or_else(|| "never (matures past day end)".into())
            );
        }
    }
    if let Some(dir) = json_dir {
        let j = crate::jobj! {
            "fig" => "fault",
            "completion_ratio" => r.completion_ratio(),
            "bound" => FAULT_TPUT_BOUND,
            "faults_seen" => r.faulty.faults_seen,
            "faults_fatal" => r.faulty.faults_fatal,
            "recoveries" => r.faulty.recoveries,
            "protected" => r.faulty.protected,
            "clean_completed" => r.clean.completed,
            "faulty_completed" => r.faulty.completed,
            "lend_leases" => r.lend.ledger.leases.len(),
            "lend_balanced" => r.lend.ledger.balanced,
        };
        super::write_json(dir, "fault", &j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_day_degradation_bounded_and_recoveries_ordered() {
        // This test asserts nothing about lending, so it runs only the
        // paired days (the lending test pays for its own day).
        let (clean, faulty) = paired_days(Scale::fast());
        let completion_ratio = if clean.completed == 0 {
            1.0
        } else {
            faulty.completed as f64 / clean.completed as f64
        };
        // Paired comparison: identical arrival streams.
        assert_eq!(
            clean.injected, faulty.injected,
            "arrival streams diverged — the comparison is not paired"
        );
        assert_eq!(clean.faults_seen, 0);
        assert!(
            faulty.faults_fatal >= 1,
            "the accelerated schedule produced no fatal fault"
        );
        assert_eq!(
            faulty.recoveries, faulty.faults_fatal,
            "a recovery never completed"
        );
        // 1) Recovery shape: Fig. 13c phase order, load-dominated outage.
        for (_hour, rep) in &faulty.recovery_reports {
            phases_ordered(&rep.trace).expect("Fig. 13c phase order");
            let load = rep
                .trace
                .steps
                .iter()
                .find(|s| s.label.contains("load"))
                .expect("load phase present");
            assert!(
                (load.end_ms - load.start_ms) / rep.outage_ms() > 0.3,
                "model load is not the long pole of the outage"
            );
        }
        // 2) Bounded degradation under the stated bound.
        assert!(
            completion_ratio >= FAULT_TPUT_BOUND,
            "completions under faults fell to {:.1}% of fault-free (bound {:.0}%)",
            completion_ratio * 100.0,
            FAULT_TPUT_BOUND * 100.0
        );
        // Protection is a subset of the timeout tally and the books
        // balance (capacity never double-counted).
        assert!(faulty.protected <= faulty.timed_out);
        assert!(faulty.ledger.balanced, "{:?}", faulty.ledger);
        assert_eq!(faulty.ledger.scrapped, faulty.faults_fatal);
        assert_eq!(faulty.total(), faulty.injected);
        assert_eq!(clean.total(), clean.injected);
    }

    #[test]
    fn lending_day_grants_and_repays_before_the_lenders_peak() {
        // Only the lending day — the paired days have their own test.
        let out = &lending_day(Scale::fast());
        assert_eq!(out.total(), out.injected);
        assert!(out.ledger.balanced, "{:?}", out.ledger);
        assert_eq!(out.ledger.minted, 0, "lending day minted capacity");
        let scene_leases: Vec<_> = out
            .ledger
            .leases
            .iter()
            .filter(|l| matches!(l.borrower, LeaseUse::Scene(_)))
            .collect();
        assert!(
            !scene_leases.is_empty(),
            "phased day produced no cross-scene lease: {:#?}",
            out.timeline
        );
        for lease in &out.ledger.leases {
            match lease.repaid_hour {
                Some(repaid) => {
                    // The call path is tick-granular: the lease is called
                    // one lead-hour early and the drain may take a tick,
                    // so repayment lands within ~2 h of the due hour (the
                    // natural-drain path repays far earlier).
                    assert!(
                        repaid <= lease.due_hour + 2.0,
                        "lease #{} repaid at {:.2} h, well after its due {:.2} h",
                        lease.id,
                        repaid,
                        lease.due_hour
                    );
                    let peak = lender_peak_hour(lease.lender, lease.granted_hour);
                    assert!(
                        repaid < peak,
                        "lease #{} repaid at {:.2} h, after the lender's peak {:.2} h",
                        lease.id,
                        repaid,
                        peak
                    );
                }
                None => {
                    // Only leases maturing too close to (or past) the end
                    // of the day may remain outstanding.
                    assert!(
                        lease.due_hour > out.end_hour - LEASE_ENFORCE_MARGIN_H,
                        "lease #{} (due {:.2} h) unpaid inside the day (end {:.2} h): {:#?}",
                        lease.id,
                        lease.due_hour,
                        out.end_hour,
                        out.timeline
                    );
                }
            }
        }
    }
}
