//! `repro --fig goodput` — goodput-driven heterogeneous autoscaling:
//! [`GoodputPlanner`] vs [`CapacityPlanner`] on a mixed-generation fleet
//! at an equal device budget.
//!
//! The catalog holds two hardware classes: `gen1` (an older generation,
//! ~4× slower on both prefill and decode, cheaper per device-hour) and
//! `gen2` (the calibrated engine). Group counts are frozen
//! (`scale_groups = false`, one group per scene), so both planners spend
//! the identical instance budget and the *class choice* is the only
//! planner-dependent decision. The capacity planner reproduces the
//! pre-trait behavior — class 0 (`gen1`) for every scene — while the
//! goodput planner places groups on the class with the highest
//! SLO-attainment goodput per device-hour (`gen2`). Under the same
//! paired arrival stream the goodput fleet must therefore strictly beat
//! the capacity fleet on SLO attainment — the Eq.-1 capability argument
//! extended across hardware generations.
//!
//! [`CapacityPlanner`]: crate::coordinator::mlops::CapacityPlanner
//! [`GoodputPlanner`]: crate::coordinator::mlops::GoodputPlanner

use crate::cluster::engine::HardwareClass;
use crate::coordinator::mlops::PlannerKind;
use crate::serving::fleet::{FleetConfig, FleetOutput};
use crate::serving::shard::run_sharded;
use crate::util::config::EngineConfig;
use crate::workload::Scenario;

use super::Scale;

/// One planner's day under the shared arrival stream.
pub struct GoodputRow {
    pub planner: &'static str,
    pub slo_attainment: f64,
    pub rps: f64,
    pub injected: usize,
    pub peak_instances: usize,
    /// `class_mix` rendered as "name:groups" pairs.
    pub class_mix: String,
}

/// The paired comparison `repro --fig goodput` reports.
pub struct GoodputCompare {
    pub capacity: GoodputRow,
    pub goodput: GoodputRow,
    /// The goodput-planned day is byte-identical across `--workers 1`
    /// and `--workers 4`.
    pub worker_invariant: bool,
}

/// Two scenes with distinct shapes so the class choice is exercised per
/// scene, not once globally.
fn mixed_scenes() -> Vec<Scenario> {
    vec![
        Scenario {
            // Prompt-heavy digest: long prompts punish slow prefill.
            name: "digest", service: "svcA",
            prompt_mean: 3200.0, prompt_cv: 0.3,
            n_prefixes: 8, prefix_frac: 0.25,
            gen_mean: 32.0, gen_cv: 0.4, weight: 1.0,
        },
        Scenario {
            // Generation-heavy chat: long outputs punish slow decode.
            name: "chat", service: "svcB",
            prompt_mean: 700.0, prompt_cv: 0.4,
            n_prefixes: 8, prefix_frac: 0.5,
            gen_mean: 180.0, gen_cv: 0.5, weight: 1.0,
        },
    ]
}

/// The mixed-generation catalog: class 0 is the older, slower, cheaper
/// generation — exactly what the first-class capacity planner picks.
fn catalog() -> Vec<HardwareClass> {
    let base = EngineConfig::default();
    let gen1 = EngineConfig {
        prefill_base_ms: base.prefill_base_ms * 4.0,
        prefill_per_token_ms: base.prefill_per_token_ms * 4.0,
        decode_base_ms: base.decode_base_ms * 4.0,
        decode_per_row_ms: base.decode_per_row_ms * 4.0,
        ..base.clone()
    };
    vec![
        HardwareClass { name: "gen1".to_string(), engine: gen1, hbm_gb: 32.0, cost_per_hour: 0.6 },
        HardwareClass { name: "gen2".to_string(), engine: base, hbm_gb: 64.0, cost_per_hour: 1.0 },
    ]
}

fn base_cfg(scale: Scale, planner: PlannerKind) -> FleetConfig {
    let fast = scale.closed_requests < Scale::full().closed_requests;
    FleetConfig {
        scenarios: mixed_scenes(),
        scenes: vec![0, 1],
        classes: catalog(),
        planner,
        // Saturating at the peaks so attainment reflects the class speed.
        peak_total_rps: 24.0,
        hours: if fast { 6.0 } else { 24.0 },
        ms_per_hour: if fast { 1_000.0 } else { 4_000.0 },
        control_period_ms: 1_000.0,
        slice_ms: 500.0,
        group_total: 6,
        // One frozen group per scene: both planners spend the identical
        // 12-instance budget; only the hardware class differs.
        min_groups_per_scene: 1,
        max_groups_per_scene: 1,
        scale_groups: false,
        seed: 0x600D,
        ..Default::default()
    }
}

fn row(out: &FleetOutput, planner: &'static str) -> GoodputRow {
    let class_mix = out
        .class_mix
        .iter()
        .map(|(k, v)| format!("{k}:{v}"))
        .collect::<Vec<_>>()
        .join(" ");
    GoodputRow {
        planner,
        slo_attainment: out.slo_attainment,
        rps: out.rps,
        injected: out.injected,
        peak_instances: out.peak_instances,
        class_mix,
    }
}

/// Run the paired day once per planner (plus the worker-invariance probe
/// on the goodput day) and package the comparison.
pub fn goodput_vs_capacity(scale: Scale) -> GoodputCompare {
    let cap = run_sharded(base_cfg(scale, PlannerKind::Capacity), 1);
    let good = run_sharded(base_cfg(scale, PlannerKind::Goodput), 1);
    let good4 = run_sharded(base_cfg(scale, PlannerKind::Goodput), 4);
    let worker_invariant =
        good.to_json().to_string_pretty() == good4.to_json().to_string_pretty();
    GoodputCompare {
        capacity: row(&cap, "capacity"),
        goodput: row(&good, "goodput"),
        worker_invariant,
    }
}

pub fn run(scale: Scale, json_dir: Option<&str>) {
    let g = goodput_vs_capacity(scale);
    let rows: Vec<(String, String)> = [&g.capacity, &g.goodput]
        .iter()
        .map(|r| {
            (
                r.planner.to_string(),
                format!(
                    "{:.0}% SLO  {:.2} rps  ({} injected, {} peak instances, classes: {})",
                    r.slo_attainment * 100.0,
                    r.rps,
                    r.injected,
                    r.peak_instances,
                    r.class_mix
                ),
            )
        })
        .collect();
    super::table(
        "Goodput planning — mixed-generation fleet day, equal device budget, paired arrivals",
        ("planner", "SLO attainment"),
        &rows,
    );
    println!(
        "goodput over capacity: {:+.1} pp SLO attainment (workers 1 vs 4 byte-identical: {})",
        (g.goodput.slo_attainment - g.capacity.slo_attainment) * 100.0,
        g.worker_invariant
    );
    // The repro is self-checking: the same bounds tier-1 pins in tests.
    assert_eq!(
        g.capacity.injected, g.goodput.injected,
        "paired runs must see the identical arrival stream"
    );
    assert_eq!(
        g.capacity.peak_instances, g.goodput.peak_instances,
        "planners must spend the same device budget"
    );
    assert!(
        g.goodput.slo_attainment > g.capacity.slo_attainment,
        "goodput {:.4} must strictly beat capacity {:.4} on SLO attainment",
        g.goodput.slo_attainment,
        g.capacity.slo_attainment
    );
    assert!(g.worker_invariant, "goodput day must be byte-identical across --workers 1 and 4");
    if let Some(dir) = json_dir {
        let j = crate::jobj! {
            "fig" => "goodput",
            "capacity_slo" => g.capacity.slo_attainment,
            "goodput_slo" => g.goodput.slo_attainment,
            "capacity_rps" => g.capacity.rps,
            "goodput_rps" => g.goodput.rps,
            "capacity_classes" => g.capacity.class_mix.as_str(),
            "goodput_classes" => g.goodput.class_mix.as_str(),
            "worker_invariant" => g.worker_invariant,
        };
        super::write_json(dir, "goodput", &j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn goodput_planner_strictly_beats_capacity_on_mixed_generations() {
        let g = goodput_vs_capacity(Scale::fast());
        // Equal budget, paired arrivals.
        assert_eq!(g.capacity.injected, g.goodput.injected);
        assert_eq!(g.capacity.peak_instances, g.goodput.peak_instances);
        // Capacity keeps the pre-trait choice (class 0, the old
        // generation); goodput moves every group to the SLO-holding one.
        assert_eq!(g.capacity.class_mix, "gen1:2");
        assert_eq!(g.goodput.class_mix, "gen2:2");
        assert!(
            g.goodput.slo_attainment > g.capacity.slo_attainment,
            "goodput {:.4} vs capacity {:.4}",
            g.goodput.slo_attainment,
            g.capacity.slo_attainment
        );
        assert!(g.worker_invariant, "workers 1 vs 4 reports differ");
    }
}
