//! Fig. 4 — block-fixed transfer fails to fully utilize bandwidth.
//!
//! (a) Extra control cost vs payload size for block-by-block transfer
//!     (smaller blocks = more confirmations = more waste).
//! (b) Achieved D2D bandwidth utilization: discrete blocks vs contiguous.

use crate::network::rdma::RdmaModel;

pub struct Fig4a {
    /// (payload MiB, block KiB, control fraction of total time).
    pub rows: Vec<(usize, usize, f64)>,
}

pub struct Fig4b {
    /// (payload MiB, utilization blocked, utilization contiguous).
    pub rows: Vec<(usize, f64, f64)>,
}

pub fn fig4a() -> Fig4a {
    let m = RdmaModel::default();
    let mut rows = Vec::new();
    for &payload_mib in &[1usize, 4, 16, 64] {
        for &block_kib in &[16usize, 64, 256, 1024] {
            let bytes = payload_mib << 20;
            let total = m.blocked_us(bytes, block_kib << 10, 3, 1);
            let wire = m.wire_us(bytes);
            rows.push((payload_mib, block_kib, (total - wire) / total));
        }
    }
    Fig4a { rows }
}

pub fn fig4b() -> Fig4b {
    let m = RdmaModel::default();
    // PageAttention-sized blocks: a 16-token block of a 13B-class model
    // split over 8 devices ≈ 1.6 MB per device per block.
    let block = 1600 << 10;
    let rows = [1usize, 2, 4, 8, 16, 32, 64, 128, 420]
        .iter()
        .map(|&mib| {
            let bytes = mib << 20;
            let ub = m.utilization(bytes, m.blocked_us(bytes, block, 3, 1));
            let uc = m.utilization(bytes, m.contiguous_us(bytes, 3, 1));
            (mib, ub, uc)
        })
        .collect();
    Fig4b { rows }
}

pub fn run(which: &str) {
    if which != "4b" {
        let f = fig4a();
        let rows: Vec<(String, String)> = f
            .rows
            .iter()
            .map(|(p, b, frac)| {
                (
                    format!("{p:>3} MiB / {b:>4} KiB blocks"),
                    format!("{:.1}% of transfer time is control", frac * 100.0),
                )
            })
            .collect();
        super::table("Fig 4a — control overhead of block-fixed transfer",
                     ("payload / block", "overhead"), &rows);
    }
    if which != "4a" {
        let f = fig4b();
        let rows: Vec<(String, String)> = f
            .rows
            .iter()
            .map(|(mib, ub, uc)| {
                (
                    format!("{mib:>3} MiB"),
                    format!(
                        "blocked {:.0}%  contiguous {:.0}%",
                        ub * 100.0,
                        uc * 100.0
                    ),
                )
            })
            .collect();
        super::table("Fig 4b — D2D bandwidth utilization",
                     ("payload", "utilization"), &rows);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_fraction_rises_as_blocks_shrink() {
        let f = fig4a();
        // For each payload size, overhead decreases with block size.
        for chunk in f.rows.chunks(4) {
            for w in chunk.windows(2) {
                assert!(w[0].2 > w[1].2, "{:?} vs {:?}", w[0], w[1]);
            }
        }
        // 16 KiB blocks on a big payload: control dominates (> 50%).
        let worst = f.rows.iter().find(|r| r.0 == 64 && r.1 == 16).unwrap();
        assert!(worst.2 > 0.5, "control fraction {}", worst.2);
    }

    #[test]
    fn contiguous_utilization_dominates_everywhere() {
        let f = fig4b();
        for (mib, ub, uc) in &f.rows {
            assert!(uc > ub, "{mib} MiB: {uc} <= {ub}");
        }
        // Large contiguous payloads approach line rate.
        assert!(f.rows.last().unwrap().2 > 0.95);
        // Blocked caps well below line rate even on the largest payload.
        assert!(f.rows.last().unwrap().1 < 0.75);
        // And the gap is material in the Fig. 14c regime (420 MiB).
        let big = f.rows.last().unwrap();
        assert!(big.2 - big.1 > 0.2);
    }
}
