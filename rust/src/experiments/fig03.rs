//! Fig. 3 — queue status is insufficient for precise TTFT.
//!
//! (a) The scheduler's pending-token TTFT estimate vs the actual T_p when
//!     70% of the prefix is cached: the estimate overshoots by ~the hit
//!     factor, and the gap widens with queue depth.
//! (b) Under heavy workload with prompt-length diversity, requests break
//!     timeouts — disproportionately the *short* ones (head-of-line
//!     blocking in local queues).

use crate::cluster::engine::{EngineModel, PrefillItem};
use crate::serving::sim::{Policy, SimConfig, Simulation, WorkloadKind};
use crate::workload::Scenario;

use super::Scale;

pub struct Fig3a {
    /// (pending tokens, estimate ms, actual ms @70% hit).
    pub rows: Vec<(usize, f64, f64)>,
}

pub struct Fig3b {
    /// Per load multiplier: (load, short-prompt timeout rate, long-prompt
    /// timeout rate).
    pub rows: Vec<(f64, f64, f64)>,
}

pub fn fig3a() -> Fig3a {
    let engine = EngineModel::default();
    let bs = 4usize;
    let prompt = 1024usize;
    // Nominal token rate the estimator divides by (tokens/ms at bs),
    // derived from the engine's *miss* behaviour — the only thing pending
    // tokens can tell you.
    let miss_batch = engine.prefill_batch_ms(&vec![
        PrefillItem { prompt_len: prompt, cached_len: 0 };
        bs
    ]);
    let token_rate = (bs * prompt) as f64 / miss_batch;
    let mut rows = Vec::new();
    for batches in 1..=8 {
        let pending = batches * bs * prompt;
        let estimate = pending as f64 / token_rate;
        // Actual: each queued batch runs with 70% of its tokens cached.
        let actual = batches as f64
            * engine.prefill_batch_ms(&vec![
                PrefillItem { prompt_len: prompt, cached_len: (prompt * 7) / 10 };
                bs
            ]);
        rows.push((pending, estimate, actual));
    }
    Fig3a { rows }
}

fn short_long_scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "short", service: "svc",
            prompt_mean: 512.0, prompt_cv: 0.2,
            n_prefixes: 4, prefix_frac: 0.6,
            gen_mean: 40.0, gen_cv: 0.4, weight: 2.0,
        },
        Scenario {
            name: "long", service: "svc",
            prompt_mean: 6144.0, prompt_cv: 0.3,
            n_prefixes: 6, prefix_frac: 0.4,
            gen_mean: 80.0, gen_cv: 0.4, weight: 1.0,
        },
    ]
}

pub fn fig3b(scale: Scale) -> Fig3b {
    let mut rows = Vec::new();
    for mult in [1.0, 2.0, 3.0, 4.0] {
        let cfg = SimConfig {
            n_p: 6,
            n_d: 3,
            policy: Policy::BaselineQueue,
            scenarios: short_long_scenarios(),
            only_scenario: None,
            workload: WorkloadKind::Open {
                rps: 3.0 * mult,
                duration_ms: scale.sim_duration_ms,
            },
            seed: 0xF16_3B,
            ..Default::default()
        };
        let out = Simulation::run(cfg);
        let rate = |i: usize| {
            let (ok, to) = out.per_scenario[i];
            if ok + to == 0 {
                0.0
            } else {
                to as f64 / (ok + to) as f64
            }
        };
        rows.push((mult, rate(0), rate(1)));
    }
    Fig3b { rows }
}

pub fn run(which: &str, scale: Scale) {
    if which != "3b" {
        let f = fig3a();
        let rows: Vec<(String, String)> = f
            .rows
            .iter()
            .map(|(pending, est, act)| {
                (
                    format!("{pending} pending tok"),
                    format!(
                        "estimate {est:.0} ms vs actual {act:.0} ms ({}x overshoot)",
                        (est / act).round()
                    ),
                )
            })
            .collect();
        super::table(
            "Fig 3a — pending-token estimate vs actual T_p (70% prefix hit)",
            ("queue", "TTFT"),
            &rows,
        );
    }
    if which != "3a" {
        let f = fig3b(scale);
        let rows: Vec<(String, String)> = f
            .rows
            .iter()
            .map(|(m, s, l)| {
                (
                    format!("load {m:.0}x"),
                    format!(
                        "short-prompt timeouts {:.1}%  long-prompt {:.1}%",
                        s * 100.0,
                        l * 100.0
                    ),
                )
            })
            .collect();
        super::table(
            "Fig 3b — timeout rates under load (baseline local queues)",
            ("load", "timeout rate"),
            &rows,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimate_overshoots_actual_with_prefix_hits() {
        let f = fig3a();
        for (pending, est, act) in &f.rows {
            assert!(
                est > &(act * 1.8),
                "at {pending} tokens: estimate {est} should be ~3x actual {act}"
            );
        }
        // Absolute gap grows with queue depth.
        let first_gap = f.rows[0].1 - f.rows[0].2;
        let last_gap = f.rows.last().unwrap().1 - f.rows.last().unwrap().2;
        assert!(last_gap > 4.0 * first_gap);
    }

    #[test]
    fn short_prompts_break_timeouts_disproportionately() {
        let f = fig3b(Scale::fast());
        let heavy = f.rows.last().unwrap();
        assert!(
            heavy.1 > 0.02,
            "short prompts should time out under heavy load: {:?}",
            heavy
        );
        // Timeout rate grows with load for shorts.
        assert!(f.rows.last().unwrap().1 >= f.rows[0].1);
    }
}
