//! Fig. 14 — designed forwarding and transfer: higher success rate, lower
//! D2D time.
//!
//! (a) Success rate under workload A → 4A: baseline (local queues +
//!     least-SSE) degrades sharply; on-demand forwarding holds ≥ 99% at A
//!     and stays far above baseline throughout.
//! (b) The success-rate/latency relationship under the same sweep
//!     (timeout checks run before and after prefill).
//! (c) Block-free transfer: average D2D time reduction and utilization.
//! (d) Transfer-time variance with multi-hop conflicts: ECMP collisions
//!     vs path-diversity spraying.

use crate::network::rdma::RdmaModel;
use crate::network::route;
use crate::serving::sim::{
    Policy, SimConfig, Simulation, TransferDiscipline, WorkloadKind,
};
use crate::util::prng::Rng;
use crate::util::stats::Summary;
use crate::workload::Scenario;

use super::Scale;

fn fig14_scenario() -> Scenario {
    // Heterogeneous prompt lengths within one scenario — the paper's
    // "the length of prompt 1 is 8k and the lengths of the others are 2k".
    Scenario {
        name: "fig14", service: "svc",
        prompt_mean: 2500.0, prompt_cv: 0.9,
        n_prefixes: 8, prefix_frac: 0.5,
        gen_mean: 60.0, gen_cv: 0.5, weight: 1.0,
    }
}

pub struct Fig14a {
    /// (load multiple of A, baseline success, on-demand success).
    pub rows: Vec<(f64, f64, f64)>,
}

const A_RPS: f64 = 2.0;

fn sweep_cfg(policy: Policy, mult: f64, scale: Scale) -> SimConfig {
    SimConfig {
        n_p: 6,
        n_d: 3,
        policy,
        scenarios: vec![fig14_scenario()],
        only_scenario: Some(0),
        workload: WorkloadKind::Open {
            rps: A_RPS * mult,
            duration_ms: scale.sim_duration_ms,
        },
        seed: 0xF16_14A,
        ..Default::default()
    }
}

pub fn fig14a(scale: Scale) -> Fig14a {
    let rows = [1.0, 2.0, 3.0, 4.0]
        .iter()
        .map(|&mult| {
            let base = Simulation::run(sweep_cfg(Policy::BaselineQueue, mult, scale));
            let ond = Simulation::run(sweep_cfg(Policy::OnDemand, mult, scale));
            (mult, base.report.success_rate(), ond.report.success_rate())
        })
        .collect();
    Fig14a { rows }
}

pub struct Fig14b {
    /// (policy, load, success, ttft p50, ttft p99).
    pub rows: Vec<(&'static str, f64, f64, f64, f64)>,
}

pub fn fig14b(scale: Scale) -> Fig14b {
    let mut rows = Vec::new();
    for &mult in &[1.0, 2.0, 4.0] {
        for (name, policy) in [
            ("baseline", Policy::BaselineQueue),
            ("on-demand", Policy::OnDemand),
        ] {
            let mut out = Simulation::run(sweep_cfg(policy, mult, scale));
            rows.push((
                name,
                mult,
                out.report.success_rate(),
                out.report.ttft.p50(),
                out.report.ttft.p99(),
            ));
        }
    }
    Fig14b { rows }
}

pub struct Fig14c {
    pub blocked_mean_ms: f64,
    pub contiguous_mean_ms: f64,
    pub blocked_util: f64,
    pub contiguous_util: f64,
    pub reduction: f64,
}

pub fn fig14c(scale: Scale) -> Fig14c {
    let mk = |transfer| SimConfig {
        n_p: 4,
        n_d: 4,
        transfer,
        scenarios: vec![Scenario {
            // Long prompts -> large KVCache payloads.
            name: "scene2", service: "svcA",
            prompt_mean: 4200.0, prompt_cv: 0.35,
            n_prefixes: 12, prefix_frac: 0.4,
            gen_mean: 120.0, gen_cv: 0.4, weight: 1.0,
        }],
        only_scenario: Some(0),
        workload: WorkloadKind::Closed {
            concurrency: 24,
            requests: scale.closed_requests,
        },
        seed: 0xF16_14C,
        ..Default::default()
    };
    let blocked = Simulation::run(mk(TransferDiscipline::Blocked));
    let contig = Simulation::run(mk(TransferDiscipline::Contiguous));
    let bm = blocked.report.xfer.mean();
    let cm = contig.report.xfer.mean();
    Fig14c {
        blocked_mean_ms: bm,
        contiguous_mean_ms: cm,
        blocked_util: blocked.xfer_utilization,
        contiguous_util: contig.xfer_utilization,
        reduction: 1.0 - cm / bm,
    }
}

pub struct Fig14d {
    /// (policy, p50 ms, p99 ms, max ms).
    pub rows: Vec<(&'static str, f64, f64, f64)>,
}

pub fn fig14d() -> Fig14d {
    // 64 concurrent KVCache moves, 8 sub-transfers each over 8 spines.
    let m = RdmaModel::default();
    let n_spines = 8;
    let subs = 8;
    let bytes_per_dev = 16 << 20;
    let mut rng = Rng::new(0xF16_14D);
    let mut rows = Vec::new();
    for (name, spray) in [("ECMP", false), ("path-sprayed", true)] {
        let mut s = Summary::new();
        for _ in 0..64 {
            // Each move shares the fabric with 3 other concurrent moves.
            let mut spine_load = vec![0usize; n_spines];
            for _ in 0..3 {
                let other = if spray {
                    route::assign_sprayed(rng.next_u64(), subs, n_spines)
                } else {
                    route::assign_ecmp(0, 1, rng.next_u64(), subs, n_spines)
                };
                for sp in other {
                    spine_load[sp] += 1;
                }
            }
            let own = if spray {
                route::assign_sprayed(rng.next_u64(), subs, n_spines)
            } else {
                route::assign_ecmp(0, 1, rng.next_u64(), subs, n_spines)
            };
            let sharers = own
                .iter()
                .map(|&sp| spine_load[sp] + 1)
                .max()
                .unwrap_or(1);
            // The move completes when its slowest sub-transfer does.
            s.add(m.contiguous_ms(bytes_per_dev, 3, sharers));
        }
        rows.push((name, s.p50(), s.p99(), s.max()));
    }
    Fig14d { rows }
}

pub fn run(which: &str, scale: Scale) {
    if which == "14" || which == "14a" {
        let f = fig14a(scale);
        let rows: Vec<(String, String)> = f
            .rows
            .iter()
            .map(|(m, b, o)| {
                (
                    format!("workload {m:.0}A"),
                    format!("baseline {:.1}%  on-demand {:.1}%", b * 100.0, o * 100.0),
                )
            })
            .collect();
        super::table("Fig 14a — success rate vs workload", ("load", "success"), &rows);
        let last = f.rows.last().unwrap();
        println!(
            "gap at 4A: {:.1} points (paper: up to 42.3)",
            (last.2 - last.1) * 100.0
        );
    }
    if which == "14" || which == "14b" {
        let f = fig14b(scale);
        let rows: Vec<(String, String)> = f
            .rows
            .iter()
            .map(|(n, m, ok, p50, p99)| {
                (
                    format!("{n} @ {m:.0}A"),
                    format!(
                        "success {:.1}%  TTFT p50 {p50:.0} ms  p99 {p99:.0} ms",
                        ok * 100.0
                    ),
                )
            })
            .collect();
        super::table("Fig 14b — success rate vs latency", ("config", "result"), &rows);
    }
    if which == "14" || which == "14c" {
        let f = fig14c(scale);
        super::table(
            "Fig 14c — block-free D2D transfer",
            ("metric", "value"),
            &[
                ("mean transfer, blocked".into(), format!("{:.2} ms", f.blocked_mean_ms)),
                ("mean transfer, contiguous".into(), format!("{:.2} ms", f.contiguous_mean_ms)),
                ("reduction".into(), format!("{:.1}% (paper: 46%)", f.reduction * 100.0)),
                ("utilization, blocked".into(), format!("{:.0}%", f.blocked_util * 100.0)),
                ("utilization, contiguous".into(), format!("{:.0}%", f.contiguous_util * 100.0)),
            ],
        );
    }
    if which == "14" || which == "14d" {
        let f = fig14d();
        let rows: Vec<(String, String)> = f
            .rows
            .iter()
            .map(|(n, p50, p99, max)| {
                (
                    n.to_string(),
                    format!("p50 {p50:.1} ms  p99 {p99:.1} ms  max {max:.1} ms"),
                )
            })
            .collect();
        super::table("Fig 14d — transfer-time variance under conflicts",
                     ("routing", "transfer time"), &rows);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_demand_holds_high_success_while_baseline_degrades() {
        let f = fig14a(Scale::fast());
        let (_, b1, o1) = f.rows[0];
        let (_, b4, o4) = *f.rows.last().unwrap();
        assert!(o1 > 0.95, "on-demand at A: {o1}");
        assert!(o4 > b4 + 0.10, "gap at 4A: ond {o4} vs base {b4}");
        assert!(b4 < b1, "baseline must degrade with load");
    }

    #[test]
    fn transfer_reduction_in_papers_ballpark() {
        let f = fig14c(Scale::fast());
        assert!(
            f.reduction > 0.25 && f.reduction < 0.75,
            "reduction {:.2} (paper: 0.46)",
            f.reduction
        );
        assert!(f.contiguous_util > f.blocked_util);
    }

    #[test]
    fn spraying_kills_the_conflict_tail() {
        let f = fig14d();
        let ecmp = &f.rows[0];
        let spray = &f.rows[1];
        assert!(ecmp.2 > spray.2, "p99: ecmp {} vs spray {}", ecmp.2, spray.2);
        assert!(ecmp.3 >= spray.3, "max tail must not be worse under spraying");
        // ECMP's conflict tail is a large multiple of its median.
        assert!(ecmp.2 > 1.3 * ecmp.1);
    }
}
