//! # pd-serve — P/D-Serve reproduction
//!
//! An end-to-end reproduction of *P/D-Serve: Serving Disaggregated Large
//! Language Model at Scale* (Jin, Wang, et al., 2024): a rust L3
//! coordinator (gateway, P/D groups, MLOps workflows, KVCache transfer)
//! driving AOT-compiled JAX/Pallas artifacts through the PJRT C API.
//!
//! Layer map (see DESIGN.md):
//! - L3 (this crate): request path — gateway on-demand forwarding,
//!   fine-grained P/D organization, block-free D2D KVCache transfer,
//!   fault detection and minimum-cost recovery.
//! - L2/L1 (python/, build time only): tiny transformer + Pallas attention
//!   kernels, lowered once to `artifacts/*.hlo.txt`.
//! - `runtime`: loads the artifacts on a PJRT CPU client and executes them
//!   on the request path; python is never invoked at serving time.
//!
//! `ARCHITECTURE.md` (crate root) maps every paper section to its module
//! and walks the fleet loop; its code blocks run as doctests here.

pub mod analysis;
pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod experiments;
pub mod gateway;
pub mod kvcache;
pub mod metrics;
pub mod network;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod util;
pub mod workload;

/// The architecture guide, compiled as doctests: every code block in
/// `ARCHITECTURE.md` must keep building against the real APIs, so the
/// paper-to-module map cannot silently rot.
#[doc = include_str!("../ARCHITECTURE.md")]
#[cfg(doctest)]
pub struct ArchitectureGuide;
