//! PageAttention-style HBM block allocator (paper §2.2.3 substrate).
//!
//! HBM left after weights/activations is carved into fixed-size blocks;
//! sequences own ordered block lists (block tables). This is the receiver-
//! side "discrete blocks" structure that block-free transfer must restore
//! into, and the allocator whose occupancy drives decode admission.

use anyhow::{anyhow, Result};

/// Fixed-size block allocator with free list and per-sequence block tables.
#[derive(Debug)]
pub struct BlockAllocator {
    block_bytes: usize,
    total_blocks: usize,
    free: Vec<u32>,
    /// seq handle -> block list; `None` entries are released handles.
    tables: Vec<Option<Vec<u32>>>,
    free_handles: Vec<u32>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SeqHandle(pub u32);

impl BlockAllocator {
    pub fn new(budget_bytes: u64, block_bytes: usize) -> Self {
        let total_blocks = (budget_bytes / block_bytes as u64) as usize;
        BlockAllocator {
            block_bytes,
            total_blocks,
            free: (0..total_blocks as u32).rev().collect(),
            tables: Vec::new(),
            free_handles: Vec::new(),
        }
    }

    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Blocks needed for `bytes` of KVCache.
    pub fn blocks_for(&self, bytes: usize) -> usize {
        bytes.div_ceil(self.block_bytes)
    }

    /// Can a sequence of `bytes` be admitted right now?
    pub fn can_fit(&self, bytes: usize) -> bool {
        self.blocks_for(bytes) <= self.free.len()
    }

    /// Allocate a block table for a new sequence. Fails (no partial
    /// allocation) if insufficient blocks — the caller then rejects or
    /// waits, never evicts silently.
    pub fn allocate(&mut self, bytes: usize) -> Result<SeqHandle> {
        let n = self.blocks_for(bytes);
        if n > self.free.len() {
            return Err(anyhow!(
                "need {n} blocks, only {} free",
                self.free.len()
            ));
        }
        let blocks: Vec<u32> = (0..n).map(|_| self.free.pop().unwrap()).collect();
        let handle = match self.free_handles.pop() {
            Some(h) => {
                self.tables[h as usize] = Some(blocks);
                h
            }
            None => {
                self.tables.push(Some(blocks));
                (self.tables.len() - 1) as u32
            }
        };
        Ok(SeqHandle(handle))
    }

    /// Grow a sequence by `extra_bytes` (decode appends KV per token).
    pub fn grow(&mut self, h: SeqHandle, cur_bytes: usize, extra_bytes: usize) -> Result<usize> {
        let have = self.blocks_for(cur_bytes.max(1));
        let need = self.blocks_for(cur_bytes + extra_bytes);
        let add = need.saturating_sub(have);
        if add > self.free.len() {
            return Err(anyhow!("grow needs {add} blocks, {} free", self.free.len()));
        }
        let table = self
            .tables
            .get_mut(h.0 as usize)
            .and_then(Option::as_mut)
            .ok_or_else(|| anyhow!("bad handle {h:?}"))?;
        for _ in 0..add {
            table.push(self.free.pop().unwrap());
        }
        Ok(add)
    }

    /// Release a sequence's blocks.
    pub fn release(&mut self, h: SeqHandle) -> Result<usize> {
        let slot = self
            .tables
            .get_mut(h.0 as usize)
            .ok_or_else(|| anyhow!("bad handle {h:?}"))?;
        let blocks = slot.take().ok_or_else(|| anyhow!("double release {h:?}"))?;
        let n = blocks.len();
        self.free.extend(blocks);
        self.free_handles.push(h.0);
        Ok(n)
    }

    pub fn table(&self, h: SeqHandle) -> Option<&[u32]> {
        self.tables.get(h.0 as usize)?.as_deref()
    }

    /// Occupancy in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        self.used_blocks() as f64 / self.total_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn allocate_release_accounting() {
        let mut a = BlockAllocator::new(1024, 64); // 16 blocks
        assert_eq!(a.total_blocks(), 16);
        let h = a.allocate(300).unwrap(); // 5 blocks
        assert_eq!(a.used_blocks(), 5);
        assert_eq!(a.table(h).unwrap().len(), 5);
        assert_eq!(a.release(h).unwrap(), 5);
        assert_eq!(a.used_blocks(), 0);
        assert!(a.release(h).is_err(), "double release");
    }

    #[test]
    fn allocation_is_all_or_nothing() {
        let mut a = BlockAllocator::new(256, 64); // 4 blocks
        let _h = a.allocate(200).unwrap(); // 4 blocks
        let before = a.free_blocks();
        assert!(a.allocate(65).is_err());
        assert_eq!(a.free_blocks(), before, "failed alloc must not leak");
    }

    #[test]
    fn grow_allocates_only_boundary_crossings() {
        let mut a = BlockAllocator::new(1024, 64);
        let h = a.allocate(64).unwrap(); // exactly 1 block
        assert_eq!(a.grow(h, 64, 10).unwrap(), 1); // crosses into block 2
        assert_eq!(a.grow(h, 74, 10).unwrap(), 0); // still inside block 2
        assert_eq!(a.table(h).unwrap().len(), 2);
    }

    #[test]
    fn handles_are_recycled() {
        let mut a = BlockAllocator::new(1024, 64);
        let h1 = a.allocate(64).unwrap();
        a.release(h1).unwrap();
        let h2 = a.allocate(64).unwrap();
        assert_eq!(h1, h2);
    }

    #[test]
    fn blocks_unique_across_live_sequences() {
        let mut a = BlockAllocator::new(4096, 64);
        let h1 = a.allocate(500).unwrap();
        let h2 = a.allocate(500).unwrap();
        let t1 = a.table(h1).unwrap().to_vec();
        let t2 = a.table(h2).unwrap().to_vec();
        for b in &t1 {
            assert!(!t2.contains(b), "block {b} double-assigned");
        }
    }

    #[test]
    fn prop_no_leak_no_double_assign() {
        let cfg = prop::Config { cases: 48, ..Default::default() };
        prop::check(
            "hbm-allocator-invariants",
            &cfg,
            |r| {
                let blocks = 8 + r.below(64);
                let seed = r.next_u64();
                (blocks, seed)
            },
            |&(blocks, seed)| {
                let mut a = BlockAllocator::new((blocks * 64) as u64, 64);
                let mut rng = Rng::new(seed);
                let mut live: Vec<(SeqHandle, usize)> = Vec::new();
                for _ in 0..200 {
                    if rng.chance(0.55) {
                        let bytes = 1 + rng.below(64 * 6);
                        if let Ok(h) = a.allocate(bytes) {
                            live.push((h, bytes));
                        }
                    } else if !live.is_empty() {
                        let idx = rng.below(live.len());
                        let (h, _) = live.swap_remove(idx);
                        a.release(h).map_err(|e| e.to_string())?;
                    }
                    // Invariant: used == sum of live tables; all blocks unique.
                    let mut seen = std::collections::BTreeSet::new();
                    let mut used = 0;
                    for (h, _) in &live {
                        let t = a.table(*h).ok_or("lost table")?;
                        used += t.len();
                        for b in t {
                            if !seen.insert(*b) {
                                return Err(format!("block {b} duplicated"));
                            }
                        }
                    }
                    if used != a.used_blocks() {
                        return Err(format!(
                            "accounting: tables hold {used}, allocator says {}",
                            a.used_blocks()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
