//! P/D instances: the unit the coordinator organizes into groups.
//!
//! An instance is a container assigned several xPU devices (all with RoCE
//! IPs), playing either the prefill or the decoding role after group
//! initialization (stateless containers have no role until then — paper
//! §3.2/§3.3). The state here is what the gateway and the simulator probe:
//! slot occupancy (accept/reject), prefix cache, health, model-load state.

use super::device::{DeviceId, RoceIp};
use super::prefix::PrefixCache;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InstanceId(pub u32);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    Prefill,
    Decode,
}

impl std::fmt::Display for Role {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Role::Prefill => write!(f, "P"),
            Role::Decode => write!(f, "D"),
        }
    }
}

/// Lifecycle of a container/instance (paper Fig. 6/7 workflows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstanceState {
    /// Fresh container: devices assigned, no role, no model.
    Stateless,
    /// RoCE connections being established to the group.
    Connecting,
    /// Loading the pre-compiled model from the file service.
    LoadingModel,
    /// Serving and sending health reports.
    Ready,
    /// Logically removed (fault or scale-in); no new traffic.
    Draining,
    Failed,
}

#[derive(Debug)]
pub struct Instance {
    pub id: InstanceId,
    pub role: Option<Role>,
    pub devices: Vec<DeviceId>,
    pub roce_ips: Vec<RoceIp>,
    pub state: InstanceState,
    /// Batch capacity (b_p or b_d).
    pub batch_size: usize,
    /// Occupied slots. For prefill this includes requests waiting for
    /// KVCache transfer ("a prompt continuously occupies one slot in
    /// prefill if it is waiting for KVCache transfer").
    pub slots_busy: usize,
    /// Prefix-aware KVCache held in this instance's HBM.
    pub prefix_cache: PrefixCache,
    /// Hardware-class catalog index this container's devices belong to
    /// (0 in a homogeneous fleet — see `cluster::engine::HardwareClass`).
    pub class_idx: usize,
}

impl Instance {
    pub fn stateless(
        id: InstanceId,
        devices: Vec<DeviceId>,
        roce_ips: Vec<RoceIp>,
        prefix_budget_bytes: usize,
        bytes_per_token: usize,
    ) -> Self {
        Instance {
            id,
            role: None,
            devices,
            roce_ips,
            state: InstanceState::Stateless,
            batch_size: 0,
            slots_busy: 0,
            prefix_cache: PrefixCache::new(prefix_budget_bytes, bytes_per_token),
            class_idx: 0,
        }
    }

    /// Tag the container with its hardware-class catalog index.
    pub fn on_class(mut self, class_idx: usize) -> Self {
        self.class_idx = class_idx;
        self
    }

    /// Assign a role + batch size (group initialization or ratio change).
    pub fn assume_role(&mut self, role: Role, batch_size: usize) {
        self.role = Some(role);
        self.batch_size = batch_size;
        self.state = InstanceState::Connecting;
    }

    /// The accept/reject signal (paper §3.5): idle means a free slot, ready
    /// state, and the right role.
    pub fn accepts(&self) -> bool {
        self.state == InstanceState::Ready
            && self.role == Some(Role::Prefill)
            && self.slots_busy < self.batch_size
    }

    pub fn free_slots(&self) -> usize {
        self.batch_size.saturating_sub(self.slots_busy)
    }

    pub fn occupy(&mut self, n: usize) -> bool {
        if self.slots_busy + n > self.batch_size {
            return false;
        }
        self.slots_busy += n;
        true
    }

    pub fn vacate(&mut self, n: usize) {
        self.slots_busy = self.slots_busy.saturating_sub(n);
    }

    /// Wipe per-role state (scale-in: "all data in the instances from
    /// removed groups are then erased").
    pub fn erase(&mut self) {
        self.role = None;
        self.batch_size = 0;
        self.slots_busy = 0;
        self.prefix_cache.clear();
        self.state = InstanceState::Stateless;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst() -> Instance {
        Instance::stateless(
            InstanceId(1),
            vec![DeviceId(0), DeviceId(1)],
            vec![
                RoceIp { region: 0, host: 1 },
                RoceIp { region: 0, host: 2 },
            ],
            1 << 20,
            4096,
        )
    }

    #[test]
    fn lifecycle_to_ready() {
        let mut i = inst();
        assert_eq!(i.state, InstanceState::Stateless);
        assert!(!i.accepts());
        i.assume_role(Role::Prefill, 4);
        assert_eq!(i.state, InstanceState::Connecting);
        i.state = InstanceState::Ready;
        assert!(i.accepts());
    }

    #[test]
    fn accept_reject_on_slots() {
        let mut i = inst();
        i.assume_role(Role::Prefill, 2);
        i.state = InstanceState::Ready;
        assert!(i.occupy(2));
        assert!(!i.accepts(), "full instance must reject");
        assert!(!i.occupy(1), "over-occupancy refused");
        i.vacate(1);
        assert!(i.accepts());
    }

    #[test]
    fn decode_role_never_accepts_prefill_traffic() {
        let mut i = inst();
        i.assume_role(Role::Decode, 16);
        i.state = InstanceState::Ready;
        assert!(!i.accepts());
    }

    #[test]
    fn erase_returns_to_stateless() {
        let mut i = inst();
        i.assume_role(Role::Prefill, 4);
        i.state = InstanceState::Ready;
        i.occupy(3);
        i.prefix_cache.insert(&[1, 2, 3]);
        i.erase();
        assert_eq!(i.state, InstanceState::Stateless);
        assert_eq!(i.role, None);
        assert_eq!(i.slots_busy, 0);
        assert!(i.prefix_cache.is_empty());
    }
}
