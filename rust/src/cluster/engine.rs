//! Analytic inference-engine performance model.
//!
//! Converts (batch size, prompt lengths, prefix hits, context lengths)
//! into TTFT/TPOT milliseconds, implementing the paper's performance
//! terms: `T_p = TTFT_bs * r_pre` (prefill time under batching and prefix
//! reuse) and `T_d = ξ + TPOT_bs * G` (decoding occupation). The constants
//! live in `util::config::EngineConfig` and are sanity-calibrated against
//! the real PJRT runtime (EXPERIMENTS.md §Calibration); all figure-level
//! claims use *relative* behaviour, matching the paper's normalized plots.
//!
//! Model:
//! - prefill batch: `base + per_tok * Σ uncached_i + quad * Σ uncached_i·ctx_i`
//!   (the quadratic term is attention reads over the full context — this is
//!   what makes 8k prompts disproportionately expensive, Fig. 3b).
//! - decode iteration: `base + per_row * rows^eff + per_ctx_us * Σ ctx_i`
//!   (rows batch sublinearly — continuous batching amortizes weights I/O).

use crate::util::config::EngineConfig;

/// One named hardware generation in a heterogeneous fleet: an engine
/// speed profile plus the capacity/cost facts the planner trades off.
///
/// A fleet's catalog is an ordered `Vec<HardwareClass>`; instances and
/// groups reference their class by index into that catalog (indices stay
/// `Copy` where a `String` name would not). An empty catalog means the
/// fleet is homogeneous on the ambient `EngineConfig` — the pre-catalog
/// behavior, bit-for-bit.
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareClass {
    /// Human-readable generation name (e.g. `"gen1"`, `"910B"`).
    pub name: String,
    /// The engine speed profile this generation runs at.
    pub engine: EngineConfig,
    /// Per-device HBM capacity in GiB (bounds resident KVCache).
    pub hbm_gb: f64,
    /// Relative device-hour price (goodput-per-cost denominator).
    pub cost_per_hour: f64,
}

impl HardwareClass {
    /// A class running the given engine profile at unit cost with a
    /// typical HBM size — the implicit class of a homogeneous fleet.
    pub fn uniform(name: &str, engine: EngineConfig) -> Self {
        HardwareClass {
            name: name.to_string(),
            engine,
            hbm_gb: 64.0,
            cost_per_hour: 1.0,
        }
    }
}

impl Default for HardwareClass {
    fn default() -> Self {
        HardwareClass::uniform("default", EngineConfig::default())
    }
}

#[derive(Clone, Debug)]
pub struct EngineModel {
    cfg: EngineConfig,
}

/// Per-request prefill description.
#[derive(Clone, Copy, Debug)]
pub struct PrefillItem {
    /// Total prompt tokens.
    pub prompt_len: usize,
    /// Tokens covered by a cached prefix (0 if miss).
    pub cached_len: usize,
}

impl PrefillItem {
    pub fn uncached(&self) -> usize {
        self.prompt_len.saturating_sub(self.cached_len)
    }
}

impl EngineModel {
    pub fn new(cfg: EngineConfig) -> Self {
        EngineModel { cfg }
    }

    pub fn cfg(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Wall time (ms) to prefill one batch.
    pub fn prefill_batch_ms(&self, items: &[PrefillItem]) -> f64 {
        if items.is_empty() {
            return 0.0;
        }
        let mut toks = 0f64;
        let mut quad = 0f64;
        for it in items {
            let u = it.uncached() as f64;
            toks += u;
            quad += u * it.prompt_len as f64;
        }
        self.cfg.prefill_base_ms
            + self.cfg.prefill_per_token_ms * toks
            + self.cfg.prefill_quad_ms * quad
    }

    /// TTFT (ms) for a single prompt prefilled alone.
    pub fn ttft_ms(&self, prompt_len: usize, cached_len: usize) -> f64 {
        self.prefill_batch_ms(&[PrefillItem { prompt_len, cached_len }])
    }

    /// The paper's `r_pre`: T_p with hit / T_p without (in (0, 1]).
    pub fn r_pre(&self, prompt_len: usize, cached_len: usize) -> f64 {
        self.ttft_ms(prompt_len, cached_len) / self.ttft_ms(prompt_len, 0)
    }

    /// Wall time (ms) of one decode iteration over `ctx_lens` (context
    /// length per active row).
    pub fn decode_iter_ms(&self, ctx_lens: &[usize]) -> f64 {
        let rows = ctx_lens.len();
        if rows == 0 {
            return 0.0;
        }
        let ctx: f64 = ctx_lens.iter().map(|&c| c as f64).sum();
        self.cfg.decode_base_ms
            + self.cfg.decode_per_row_ms * (rows as f64).powf(self.cfg.batch_efficiency)
            + self.cfg.decode_per_ctx_token_us * ctx / 1000.0
    }

    /// TPOT (ms between tokens) for one request decoding at batch `bs`:
    /// every request advances one token per iteration, so TPOT equals the
    /// full iteration wall time (NOT iteration/bs — that is the per-token
    /// *engine* cost, see `engine_ms_per_token`).
    pub fn tpot_ms(&self, bs: usize, ctx: usize) -> f64 {
        self.decode_iter_ms(&vec![ctx; bs])
    }

    /// Engine-seconds each generated token costs at batch `bs` (the
    /// amortized serial-resource view: iteration wall time / bs).
    pub fn engine_ms_per_token(&self, bs: usize, ctx: usize) -> f64 {
        self.decode_iter_ms(&vec![ctx; bs]) / bs.max(1) as f64
    }

    /// The paper's `T_d` for one request: transfer time ξ plus `G` decode
    /// iterations' worth of occupation (`T_d = ξ + TPOT_bs · G`).
    pub fn t_d_ms(&self, xfer_ms: f64, bs: usize, ctx: usize, gen_tokens: usize) -> f64 {
        xfer_ms + self.tpot_ms(bs, ctx) * gen_tokens as f64
    }

    /// Prefill processing capability: batches/sec * batch = requests/sec,
    /// for homogeneous prompts (paper's `n_p b_p / T_p` with n_p = 1).
    pub fn prefill_rps(&self, bs: usize, prompt_len: usize, cached_len: usize) -> f64 {
        let items = vec![PrefillItem { prompt_len, cached_len }; bs];
        let t = self.prefill_batch_ms(&items);
        bs as f64 / (t / 1000.0)
    }

    /// Decode processing capability: requests/sec for prompts generating
    /// `gen_tokens`, at batch `bs` and mean context `ctx`
    /// (paper's `n_d b_d / T_d` with n_d = 1, ξ folded in).
    pub fn decode_rps(&self, bs: usize, ctx: usize, gen_tokens: usize, xfer_ms: f64) -> f64 {
        let td = xfer_ms + self.tpot_ms(bs, ctx) * gen_tokens as f64;
        bs as f64 / (td / 1000.0)
    }
}

impl Default for EngineModel {
    fn default() -> Self {
        EngineModel::new(EngineConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> EngineModel {
        EngineModel::default()
    }

    #[test]
    fn ttft_monotone_in_length() {
        let m = m();
        let mut prev = 0.0;
        for len in [128, 512, 1024, 4096, 8192] {
            let t = m.ttft_ms(len, 0);
            assert!(t > prev, "TTFT must grow with length");
            prev = t;
        }
    }

    #[test]
    fn prefix_hit_reduces_ttft_proportionally() {
        // Fig. 1b: higher hit rate -> lower T_p, roughly linearly.
        let m = m();
        let full = m.ttft_ms(1024, 0);
        let hit70 = m.ttft_ms(1024, 716);
        let hit30 = m.ttft_ms(1024, 307);
        assert!(hit70 < hit30 && hit30 < full);
        let r = m.r_pre(1024, 716);
        assert!(r > 0.2 && r < 0.5, "70% hit -> r_pre ≈ 0.3-ish, got {r}");
    }

    #[test]
    fn quadratic_term_penalizes_long_prompts() {
        // 8k prompt costs more than 8x a 1k prompt (Fig. 3b's asymmetry).
        let m = m();
        let t1k = m.ttft_ms(1024, 0);
        let t8k = m.ttft_ms(8192, 0);
        assert!(t8k > 8.0 * t1k, "t8k={t8k} t1k={t1k}");
    }

    #[test]
    fn decode_batching_is_sublinear() {
        let m = m();
        let t1 = m.decode_iter_ms(&[512]);
        let t8 = m.decode_iter_ms(&vec![512; 8]);
        assert!(t8 < 8.0 * t1, "batching must amortize");
        assert!(t8 > t1, "more rows still cost more");
        // Per-token engine cost improves with batch; per-request TPOT
        // degrades only mildly (the continuous-batching tradeoff).
        assert!(m.engine_ms_per_token(8, 512) < m.engine_ms_per_token(1, 512));
        assert!(m.tpot_ms(8, 512) < 4.0 * m.tpot_ms(1, 512));
    }

    #[test]
    fn t_d_grows_with_tokens_generated() {
        // Fig. 12b: more generated tokens -> longer decode occupation.
        let m = m();
        let short = m.decode_rps(8, 512, 64, 10.0);
        let long = m.decode_rps(8, 512, 512, 10.0);
        assert!(short > 3.0 * long, "short={short} long={long}");
    }

    #[test]
    fn rps_capability_orders() {
        // Capability drops with prompt length (prefill) and gen len (decode).
        let m = m();
        assert!(m.prefill_rps(4, 512, 0) > m.prefill_rps(4, 2048, 0));
        assert!(m.decode_rps(16, 512, 128, 5.0) > m.decode_rps(16, 512, 512, 5.0));
    }

    #[test]
    fn empty_batches_cost_nothing() {
        let m = m();
        assert_eq!(m.prefill_batch_ms(&[]), 0.0);
        assert_eq!(m.decode_iter_ms(&[]), 0.0);
    }
}
