//! Prefix-aware KVCache registry with LRU eviction under an HBM budget.
//!
//! The paper's premise (§2.2.1): each prefill instance can only keep a few
//! prefixes' KVCaches resident in HBM, so the hit rate depends on how
//! prompts are organized across instances. Fine-grained P/D groups route
//! homologous prompts (one scenario) to the same instances, raising hit
//! rates without host-memory spill.
//!
//! Entries are token sequences; `lookup` returns the longest cached entry
//! that prefix-matches the prompt (the number of tokens whose KV need not
//! be recomputed). Insertion evicts least-recently-used entries when the
//! byte budget would be exceeded.

/// One cached prefix.
#[derive(Clone, Debug)]
struct Entry {
    tokens: Vec<i32>,
    bytes: usize,
    last_used: u64,
}

#[derive(Debug)]
pub struct PrefixCache {
    budget_bytes: usize,
    bytes_per_token: usize,
    used_bytes: usize,
    entries: Vec<Entry>,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl PrefixCache {
    pub fn new(budget_bytes: usize, bytes_per_token: usize) -> Self {
        PrefixCache {
            budget_bytes,
            bytes_per_token,
            used_bytes: 0,
            entries: Vec::new(),
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }
    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Longest cached prefix of `prompt`, in tokens. Marks the entry used.
    pub fn lookup(&mut self, prompt: &[i32]) -> usize {
        self.tick += 1;
        let mut best: Option<(usize, usize)> = None; // (len, idx)
        for (i, e) in self.entries.iter().enumerate() {
            if e.tokens.len() <= prompt.len()
                && prompt[..e.tokens.len()] == e.tokens[..]
            {
                let len = e.tokens.len();
                if best.map(|(l, _)| len > l).unwrap_or(true) {
                    best = Some((len, i));
                }
            }
        }
        match best {
            Some((len, i)) => {
                self.entries[i].last_used = self.tick;
                self.hits += 1;
                len
            }
            None => {
                self.misses += 1;
                0
            }
        }
    }

    /// Insert a prefix (e.g. after a prefill computed it). Returns false if
    /// the prefix alone exceeds the whole budget.
    pub fn insert(&mut self, prefix: &[i32]) -> bool {
        if prefix.is_empty() {
            return true;
        }
        // Already present (exact)?
        if self
            .entries
            .iter()
            .any(|e| e.tokens.len() == prefix.len() && e.tokens[..] == *prefix)
        {
            return true;
        }
        let bytes = prefix.len() * self.bytes_per_token;
        if bytes > self.budget_bytes {
            return false;
        }
        while self.used_bytes + bytes > self.budget_bytes {
            self.evict_lru();
        }
        self.tick += 1;
        self.entries.push(Entry {
            tokens: prefix.to_vec(),
            bytes,
            last_used: self.tick,
        });
        self.used_bytes += bytes;
        true
    }

    fn evict_lru(&mut self) {
        if let Some((idx, _)) = self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_used)
        {
            let e = self.entries.swap_remove(idx);
            self.used_bytes -= e.bytes;
        }
    }

    /// Observed hit rate (lookups with any prefix match).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    pub fn clear(&mut self) {
        self.entries.clear();
        self.used_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn toks(xs: &[i32]) -> Vec<i32> {
        xs.to_vec()
    }

    #[test]
    fn longest_prefix_wins() {
        let mut c = PrefixCache::new(10_000, 10);
        c.insert(&toks(&[1, 2]));
        c.insert(&toks(&[1, 2, 3, 4]));
        assert_eq!(c.lookup(&[1, 2, 3, 4, 5, 6]), 4);
        assert_eq!(c.lookup(&[1, 2, 9]), 2);
        assert_eq!(c.lookup(&[9, 9]), 0);
    }

    #[test]
    fn entry_longer_than_prompt_does_not_match() {
        let mut c = PrefixCache::new(10_000, 10);
        c.insert(&toks(&[1, 2, 3, 4]));
        assert_eq!(c.lookup(&[1, 2]), 0);
    }

    #[test]
    fn lru_eviction_under_budget() {
        // Budget for exactly two 4-token entries (4 * 10 * 2 = 80).
        let mut c = PrefixCache::new(80, 10);
        c.insert(&toks(&[1, 1, 1, 1]));
        c.insert(&toks(&[2, 2, 2, 2]));
        // Touch entry 1 so entry 2 is LRU.
        assert_eq!(c.lookup(&[1, 1, 1, 1, 5]), 4);
        c.insert(&toks(&[3, 3, 3, 3]));
        assert_eq!(c.lookup(&[2, 2, 2, 2, 5]), 0, "entry 2 evicted");
        assert_eq!(c.lookup(&[1, 1, 1, 1, 5]), 4, "entry 1 kept");
        assert_eq!(c.lookup(&[3, 3, 3, 3, 5]), 4);
    }

    #[test]
    fn oversized_prefix_rejected() {
        let mut c = PrefixCache::new(30, 10);
        assert!(!c.insert(&toks(&[1, 2, 3, 4])));
        assert!(c.insert(&toks(&[1, 2, 3])));
    }

    #[test]
    fn duplicate_insert_no_double_count() {
        let mut c = PrefixCache::new(1000, 10);
        c.insert(&toks(&[1, 2, 3]));
        let used = c.used_bytes();
        c.insert(&toks(&[1, 2, 3]));
        assert_eq!(c.used_bytes(), used);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut c = PrefixCache::new(1000, 10);
        c.insert(&toks(&[7, 7]));
        c.lookup(&[7, 7, 1]); // hit
        c.lookup(&[8, 8]); // miss
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn prop_used_bytes_never_exceeds_budget() {
        let cfg = prop::Config { cases: 48, ..Default::default() };
        prop::check(
            "prefix-budget",
            &cfg,
            |r| (200 + r.below(2000), r.next_u64()),
            |&(budget, seed)| {
                let mut c = PrefixCache::new(budget, 10);
                let mut rng = Rng::new(seed);
                for _ in 0..300 {
                    let len = 1 + rng.below(40);
                    let head = rng.below(5) as i32;
                    let prefix: Vec<i32> = std::iter::once(head)
                        .chain((1..len).map(|i| i as i32))
                        .collect();
                    if rng.chance(0.7) {
                        c.insert(&prefix);
                    } else {
                        c.lookup(&prefix);
                    }
                    if c.used_bytes() > budget {
                        return Err(format!(
                            "budget {} exceeded: {}",
                            budget,
                            c.used_bytes()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
