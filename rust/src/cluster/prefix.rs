//! Prefix-aware KVCache registry with LRU eviction under an HBM budget.
//!
//! The paper's premise (§2.2.1): each prefill instance can only keep a few
//! prefixes' KVCaches resident in HBM, so the hit rate depends on how
//! prompts are organized across instances. Fine-grained P/D groups route
//! homologous prompts (one scenario) to the same instances, raising hit
//! rates without host-memory spill.
//!
//! Entries are token sequences; `lookup` returns the longest cached entry
//! that prefix-matches the prompt (the number of tokens whose KV need not
//! be recomputed). Insertion evicts least-recently-used entries when the
//! byte budget would be exceeded.
//!
//! Entries are indexed by their first token, so a lookup probes one small
//! bucket instead of scanning every entry. The simulator keeps one cache
//! per prefill instance and consults it on every accept probe and batch
//! admission, so a linear scan would make that hot loop quadratic in the
//! number of live prefixes (`benches/router.rs` guards the scaling).
//!
//! `SharedPrefixCache` is the shared-handle view: the owning instance and
//! any observer (router experiments, per-instance readouts) clone the
//! handle and see one cache. Single-threaded by design — the simulator
//! and the real engine both run their logical instances on one thread.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// Identity of one prefix stream at simulation granularity: which
/// scenario it belongs to and which of that scenario's prefix pools it
/// is. Every prefix-keyed map — the tiered host/HBM cache
/// (`cluster::hostmem::TieredPrefixCache`), the simulator's
/// canonical-length memo, the fleet's route-hash memo — keys on this
/// one type, so the tiers cannot be keyed inconsistently when the host
/// tier is wired into serving.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PrefixKey {
    /// Catalogue index of the scenario the stream belongs to.
    pub scenario: usize,
    /// Prefix-pool index within the scenario.
    pub prefix_id: usize,
}

impl PrefixKey {
    /// Key for prefix `prefix_id` of scenario `scenario`.
    pub fn new(scenario: usize, prefix_id: usize) -> Self {
        PrefixKey { scenario, prefix_id }
    }
}

/// One cached prefix.
#[derive(Clone, Debug)]
struct Entry {
    tokens: Vec<i32>,
    bytes: usize,
    last_used: u64,
}

#[derive(Debug)]
pub struct PrefixCache {
    budget_bytes: usize,
    bytes_per_token: usize,
    used_bytes: usize,
    /// First token → entries starting with it.
    buckets: BTreeMap<i32, Vec<Entry>>,
    n_entries: usize,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl PrefixCache {
    pub fn new(budget_bytes: usize, bytes_per_token: usize) -> Self {
        PrefixCache {
            budget_bytes,
            bytes_per_token,
            used_bytes: 0,
            buckets: BTreeMap::new(),
            n_entries: 0,
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }
    pub fn len(&self) -> usize {
        self.n_entries
    }
    pub fn is_empty(&self) -> bool {
        self.n_entries == 0
    }

    /// Longest cached prefix of `prompt` in tokens, without touching LRU
    /// state or hit accounting — the prediction the prefill's admission
    /// check runs (it knows its own cache; a remote estimator does not).
    pub fn peek(&self, prompt: &[i32]) -> usize {
        let Some(&head) = prompt.first() else { return 0 };
        let Some(bucket) = self.buckets.get(&head) else { return 0 };
        bucket
            .iter()
            .filter(|e| {
                e.tokens.len() <= prompt.len()
                    && prompt[..e.tokens.len()] == e.tokens[..]
            })
            .map(|e| e.tokens.len())
            .max()
            .unwrap_or(0)
    }

    /// Longest cached prefix of `prompt`, in tokens. Marks the entry used.
    pub fn lookup(&mut self, prompt: &[i32]) -> usize {
        self.tick += 1;
        let tick = self.tick;
        let Some(&head) = prompt.first() else {
            self.misses += 1;
            return 0;
        };
        let mut best: Option<(usize, usize)> = None; // (len, idx)
        if let Some(bucket) = self.buckets.get_mut(&head) {
            for (i, e) in bucket.iter().enumerate() {
                if e.tokens.len() <= prompt.len()
                    && prompt[..e.tokens.len()] == e.tokens[..]
                {
                    let len = e.tokens.len();
                    if best.map(|(l, _)| len > l).unwrap_or(true) {
                        best = Some((len, i));
                    }
                }
            }
            if let Some((len, i)) = best {
                bucket[i].last_used = tick;
                self.hits += 1;
                return len;
            }
        }
        self.misses += 1;
        0
    }

    /// Insert a prefix (e.g. after a prefill computed it). Returns false if
    /// the prefix alone exceeds the whole budget.
    pub fn insert(&mut self, prefix: &[i32]) -> bool {
        let Some(&head) = prefix.first() else { return true };
        // Already present (exact)?
        if self
            .buckets
            .get(&head)
            .map(|b| b.iter().any(|e| e.tokens[..] == *prefix))
            .unwrap_or(false)
        {
            return true;
        }
        let bytes = prefix.len() * self.bytes_per_token;
        if bytes > self.budget_bytes {
            return false;
        }
        while self.used_bytes + bytes > self.budget_bytes {
            self.evict_lru();
        }
        self.tick += 1;
        let entry = Entry {
            tokens: prefix.to_vec(),
            bytes,
            last_used: self.tick,
        };
        self.buckets.entry(head).or_default().push(entry);
        self.n_entries += 1;
        self.used_bytes += bytes;
        true
    }

    fn evict_lru(&mut self) {
        let mut victim: Option<(i32, usize, u64)> = None; // (head, idx, last_used)
        for (&head, bucket) in &self.buckets {
            for (i, e) in bucket.iter().enumerate() {
                if victim.map(|(_, _, lu)| e.last_used < lu).unwrap_or(true) {
                    victim = Some((head, i, e.last_used));
                }
            }
        }
        if let Some((head, i, _)) = victim {
            let bucket = self.buckets.get_mut(&head).expect("victim bucket exists");
            let e = bucket.swap_remove(i);
            if bucket.is_empty() {
                self.buckets.remove(&head);
            }
            self.used_bytes -= e.bytes;
            self.n_entries -= 1;
        }
    }

    /// Observed hit rate (lookups with any prefix match).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    /// Lifetime lookups that matched any cached prefix.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lifetime lookups (hits + misses).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn clear(&mut self) {
        self.buckets.clear();
        self.n_entries = 0;
        self.used_bytes = 0;
    }
}

/// Clone-able shared handle onto one `PrefixCache`. The simulator's
/// per-prefill-instance caches are held through this so the instance
/// (admission + batch launch) and any observer (experiments, tests) read
/// and warm the same state.
#[derive(Clone, Debug)]
pub struct SharedPrefixCache(Rc<RefCell<PrefixCache>>);

impl SharedPrefixCache {
    pub fn new(budget_bytes: usize, bytes_per_token: usize) -> Self {
        SharedPrefixCache(Rc::new(RefCell::new(PrefixCache::new(
            budget_bytes,
            bytes_per_token,
        ))))
    }

    pub fn peek(&self, prompt: &[i32]) -> usize {
        self.0.borrow().peek(prompt)
    }

    pub fn lookup(&self, prompt: &[i32]) -> usize {
        self.0.borrow_mut().lookup(prompt)
    }

    pub fn insert(&self, prefix: &[i32]) -> bool {
        self.0.borrow_mut().insert(prefix)
    }

    pub fn hit_rate(&self) -> f64 {
        self.0.borrow().hit_rate()
    }

    pub fn hits(&self) -> u64 {
        self.0.borrow().hits()
    }

    pub fn lookups(&self) -> u64 {
        self.0.borrow().lookups()
    }

    pub fn len(&self) -> usize {
        self.0.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.borrow().is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.0.borrow().used_bytes()
    }

    pub fn clear(&self) {
        self.0.borrow_mut().clear()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn toks(xs: &[i32]) -> Vec<i32> {
        xs.to_vec()
    }

    #[test]
    fn longest_prefix_wins() {
        let mut c = PrefixCache::new(10_000, 10);
        c.insert(&toks(&[1, 2]));
        c.insert(&toks(&[1, 2, 3, 4]));
        assert_eq!(c.lookup(&[1, 2, 3, 4, 5, 6]), 4);
        assert_eq!(c.lookup(&[1, 2, 9]), 2);
        assert_eq!(c.lookup(&[9, 9]), 0);
    }

    #[test]
    fn entry_longer_than_prompt_does_not_match() {
        let mut c = PrefixCache::new(10_000, 10);
        c.insert(&toks(&[1, 2, 3, 4]));
        assert_eq!(c.lookup(&[1, 2]), 0);
    }

    #[test]
    fn peek_matches_lookup_without_mutation() {
        let mut c = PrefixCache::new(10_000, 10);
        c.insert(&toks(&[1, 2, 3]));
        assert_eq!(c.peek(&[1, 2, 3, 4]), 3);
        assert_eq!(c.peek(&[2, 2]), 0);
        // peek counted nothing.
        assert_eq!(c.lookups(), 0);
        assert_eq!(c.lookup(&[1, 2, 3, 4]), 3);
        assert_eq!(c.lookups(), 1);
        assert_eq!(c.hits(), 1);
    }

    #[test]
    fn lru_eviction_under_budget() {
        // Budget for exactly two 4-token entries (4 * 10 * 2 = 80).
        let mut c = PrefixCache::new(80, 10);
        c.insert(&toks(&[1, 1, 1, 1]));
        c.insert(&toks(&[2, 2, 2, 2]));
        // Touch entry 1 so entry 2 is LRU.
        assert_eq!(c.lookup(&[1, 1, 1, 1, 5]), 4);
        c.insert(&toks(&[3, 3, 3, 3]));
        assert_eq!(c.lookup(&[2, 2, 2, 2, 5]), 0, "entry 2 evicted");
        assert_eq!(c.lookup(&[1, 1, 1, 1, 5]), 4, "entry 1 kept");
        assert_eq!(c.lookup(&[3, 3, 3, 3, 5]), 4);
    }

    #[test]
    fn oversized_prefix_rejected() {
        let mut c = PrefixCache::new(30, 10);
        assert!(!c.insert(&toks(&[1, 2, 3, 4])));
        assert!(c.insert(&toks(&[1, 2, 3])));
    }

    #[test]
    fn duplicate_insert_no_double_count() {
        let mut c = PrefixCache::new(1000, 10);
        c.insert(&toks(&[1, 2, 3]));
        let used = c.used_bytes();
        c.insert(&toks(&[1, 2, 3]));
        assert_eq!(c.used_bytes(), used);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut c = PrefixCache::new(1000, 10);
        c.insert(&toks(&[7, 7]));
        c.lookup(&[7, 7, 1]); // hit
        c.lookup(&[8, 8]); // miss
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shared_handles_see_one_cache() {
        let a = SharedPrefixCache::new(1000, 10);
        let b = a.clone();
        a.insert(&[4, 5, 6]);
        assert_eq!(b.lookup(&[4, 5, 6, 7]), 3);
        assert_eq!(a.hits(), 1);
        assert_eq!(b.len(), 1);
        b.clear();
        assert!(a.is_empty());
    }

    #[test]
    fn prop_used_bytes_never_exceeds_budget() {
        let cfg = prop::Config { cases: 48, ..Default::default() };
        prop::check(
            "prefix-budget",
            &cfg,
            |r| (200 + r.below(2000), r.next_u64()),
            |&(budget, seed)| {
                let mut c = PrefixCache::new(budget, 10);
                let mut rng = Rng::new(seed);
                for _ in 0..300 {
                    let len = 1 + rng.below(40);
                    let head = rng.below(5) as i32;
                    let prefix: Vec<i32> = std::iter::once(head)
                        .chain((1..len).map(|i| i as i32))
                        .collect();
                    if rng.chance(0.7) {
                        c.insert(&prefix);
                    } else {
                        c.lookup(&prefix);
                    }
                    if c.used_bytes() > budget {
                        return Err(format!(
                            "budget {} exceeded: {}",
                            budget,
                            c.used_bytes()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Reference implementation: the pre-index linear scan, kept verbatim
    /// for the equivalence property below.
    struct LinearRef {
        budget: usize,
        bpt: usize,
        used: usize,
        entries: Vec<(Vec<i32>, usize, u64)>, // (tokens, bytes, last_used)
        tick: u64,
    }

    impl LinearRef {
        fn new(budget: usize, bpt: usize) -> Self {
            LinearRef { budget, bpt, used: 0, entries: Vec::new(), tick: 0 }
        }

        fn lookup(&mut self, prompt: &[i32]) -> usize {
            self.tick += 1;
            let mut best: Option<(usize, usize)> = None;
            for (i, (t, _, _)) in self.entries.iter().enumerate() {
                if t.len() <= prompt.len()
                    && prompt[..t.len()] == t[..]
                    && best.map(|(l, _)| t.len() > l).unwrap_or(true)
                {
                    best = Some((t.len(), i));
                }
            }
            match best {
                Some((len, i)) => {
                    self.entries[i].2 = self.tick;
                    len
                }
                None => 0,
            }
        }

        fn insert(&mut self, prefix: &[i32]) -> bool {
            if prefix.is_empty() {
                return true;
            }
            if self.entries.iter().any(|(t, _, _)| t[..] == *prefix) {
                return true;
            }
            let bytes = prefix.len() * self.bpt;
            if bytes > self.budget {
                return false;
            }
            while self.used + bytes > self.budget {
                if let Some((i, _)) = self
                    .entries
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, (_, _, lu))| *lu)
                {
                    let (_, b, _) = self.entries.swap_remove(i);
                    self.used -= b;
                }
            }
            self.tick += 1;
            self.entries.push((prefix.to_vec(), bytes, self.tick));
            self.used += bytes;
            true
        }
    }

    /// Satellite: the first-token-bucket index is an observably pure
    /// optimization — lookup results, sizes and byte accounting match the
    /// linear-scan reference on any op sequence.
    #[test]
    fn prop_bucketed_index_equivalent_to_linear_scan() {
        let cfg = prop::Config { cases: 64, ..Default::default() };
        prop::check(
            "prefix-bucket-equivalence",
            &cfg,
            |r| (300 + r.below(1500), r.next_u64()),
            |&(budget, seed)| {
                let mut fast = PrefixCache::new(budget, 7);
                let mut slow = LinearRef::new(budget, 7);
                let mut rng = Rng::new(seed);
                for step in 0..250 {
                    // Small alphabet of heads + shared tails: plenty of
                    // bucket collisions and partial prefix overlaps.
                    let head = rng.below(4) as i32;
                    let len = 1 + rng.below(20);
                    let stream = rng.below(3) as i32;
                    let seq: Vec<i32> = std::iter::once(head)
                        .chain((1..len).map(|i| stream * 100 + i as i32))
                        .collect();
                    if rng.chance(0.6) {
                        let a = fast.insert(&seq);
                        let b = slow.insert(&seq);
                        if a != b {
                            return Err(format!("step {step}: insert {a} != {b}"));
                        }
                    } else {
                        let a = fast.lookup(&seq);
                        let b = slow.lookup(&seq);
                        if a != b {
                            return Err(format!("step {step}: lookup {a} != {b}"));
                        }
                    }
                    if fast.used_bytes() != slow.used
                        || fast.len() != slow.entries.len()
                    {
                        return Err(format!(
                            "step {step}: {}B/{} entries vs {}B/{}",
                            fast.used_bytes(),
                            fast.len(),
                            slow.used,
                            slow.entries.len()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
