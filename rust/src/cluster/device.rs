//! One xPU device: identity, placement, RoCE address, HBM budget, health.
//!
//! Devices are the unit the paper's fault model operates on: "about 1 or 2
//! faults occur per week over the cluster with 400 GPUs … with tens of
//! thousands of xPUs, the faults are very common (both recoverable and
//! unrecoverable)". Faults are classified into levels (paper Fig. 8); only
//! some require node-level recovery.

use std::fmt;

/// Globally unique device id (dense index into the topology).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DeviceId(pub u32);

/// A RoCE v2 address. The paper's format is `<P, {<IP1, …>, …}>`; we keep
/// the IP as a synthetic /16-style pair (region-scoped, "maximum RoCE IPs
/// are limited in a region, in thousands").
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RoceIp {
    pub region: u16,
    pub host: u16,
}

impl fmt::Display for RoceIp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // 10.<region>.<hi>.<lo> — purely cosmetic.
        write!(
            f,
            "10.{}.{}.{}",
            self.region,
            self.host >> 8,
            self.host & 0xff
        )
    }
}

/// Fault classification (paper §3.4: "the faults are classified into
/// multiple levels, in which some are recoverable without node-level
/// recovery").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultLevel {
    /// Transient — recoverable in place (e.g. link flap, ECC-corrected).
    Recoverable,
    /// Device lost — instance must be substituted, node survives.
    DeviceFatal,
    /// Node lost — all instances on the node must be substituted.
    NodeFatal,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    Ok,
    Faulty(FaultLevel),
}

/// One xPU device.
#[derive(Clone, Debug)]
pub struct Device {
    pub id: DeviceId,
    pub roce: RoceIp,
    /// Placement: region / rack / node / local index — filled by topology.
    pub region: u16,
    pub rack: u16,
    pub node: u32,
    pub local_index: u8,
    /// HBM capacity in bytes.
    pub hbm_bytes: u64,
    /// HBM already pinned by weights + activations + reserved space; the
    /// remainder is the KVCache budget (paper: "the space left for KVCache
    /// is at least 30%").
    pub hbm_reserved_bytes: u64,
    pub health: Health,
}

impl Device {
    pub fn kvcache_budget_bytes(&self) -> u64 {
        self.hbm_bytes.saturating_sub(self.hbm_reserved_bytes)
    }

    pub fn is_healthy(&self) -> bool {
        matches!(self.health, Health::Ok)
    }

    /// Whether this fault can clear without substitution.
    pub fn recoverable_in_place(&self) -> bool {
        matches!(self.health, Health::Faulty(FaultLevel::Recoverable))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Device {
        Device {
            id: DeviceId(7),
            roce: RoceIp { region: 1, host: 258 },
            region: 1,
            rack: 0,
            node: 3,
            local_index: 2,
            hbm_bytes: 32 << 30,
            hbm_reserved_bytes: 20 << 30,
            health: Health::Ok,
        }
    }

    #[test]
    fn kvcache_budget() {
        let d = dev();
        assert_eq!(d.kvcache_budget_bytes(), 12 << 30);
        let mut d2 = d.clone();
        d2.hbm_reserved_bytes = 40 << 30;
        assert_eq!(d2.kvcache_budget_bytes(), 0);
    }

    #[test]
    fn health_transitions() {
        let mut d = dev();
        assert!(d.is_healthy());
        d.health = Health::Faulty(FaultLevel::Recoverable);
        assert!(!d.is_healthy());
        assert!(d.recoverable_in_place());
        d.health = Health::Faulty(FaultLevel::DeviceFatal);
        assert!(!d.recoverable_in_place());
    }

    #[test]
    fn roce_ip_display() {
        let ip = RoceIp { region: 3, host: 0x0102 };
        assert_eq!(ip.to_string(), "10.3.1.2");
    }
}
