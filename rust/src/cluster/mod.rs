//! Cluster substrate: the simulated xPU fleet the coordinator manages.
//!
//! - `device`: one xPU (NPU) — HBM capacity, RoCE IP, health/fault levels.
//! - `hbm`: PageAttention-style fixed-size block allocator over HBM.
//! - `prefix`: prefix-aware KVCache (token trie + LRU) with HBM accounting.
//! - `engine`: the analytic inference perf model — `TTFT(bs, len, hit)` and
//!   `TPOT(bs, ctx)` — calibrated against the real PJRT runtime.
//! - `instance`: a P or D instance (a container holding several devices)
//!   with the accept/reject and slot state the gateway interacts with.

pub mod device;
pub mod engine;
pub mod hbm;
pub mod hostmem;
pub mod instance;
pub mod prefix;

pub use device::{Device, DeviceId, FaultLevel, Health, RoceIp};
pub use engine::EngineModel;
pub use hbm::BlockAllocator;
pub use instance::{Instance, InstanceId, Role};
pub use prefix::{PrefixCache, SharedPrefixCache};
