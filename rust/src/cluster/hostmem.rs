//! Two-tier prefix-aware KVCache: HBM + host-memory pool (paper §6.2,
//! Discussion/extension: multi-turn conversation).
//!
//! "With further growth on the number of prefixes and content length …
//! available host memory is useful since its capacity is relatively
//! large. Although loading KVCache from host (local or remote) incurs
//! extra overhead, compared with the inference on the entire prompt, such
//! overhead is gradually acceptable."
//!
//! Lookup policy: HBM hit is free; a host hit pays a load cost
//! (bytes / host_load_gbps) and promotes the entry to HBM (evicting LRU
//! HBM entries into the host tier — a flush, also charged); a miss
//! computes from scratch and installs in HBM. Fine-grained P/D
//! organization raises both tiers' hit rates because one group serves one
//! scenario (the affinity argument of §6.2).

use std::collections::BTreeMap;

use crate::cluster::prefix::PrefixKey;

/// Where a lookup was served from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TierHit {
    Hbm,
    /// Served from host memory; carries the load time in ms.
    Host,
    Miss,
}

#[derive(Clone, Debug)]
struct Entry {
    bytes: usize,
    last_used: u64,
}

/// Two-tier LRU keyed by [`PrefixKey`] at simulation granularity.
#[derive(Debug)]
pub struct TieredPrefixCache {
    hbm: BTreeMap<PrefixKey, Entry>,
    host: BTreeMap<PrefixKey, Entry>,
    hbm_budget: usize,
    host_budget: usize,
    hbm_used: usize,
    host_used: usize,
    /// Host<->HBM staging bandwidth (GB/s) — PCIe-class.
    pub host_load_gbps: f64,
    tick: u64,
    pub hbm_hits: u64,
    pub host_hits: u64,
    pub misses: u64,
    /// Total ms spent loading/flushing across the run.
    pub staging_ms: f64,
}

impl TieredPrefixCache {
    pub fn new(hbm_budget: usize, host_budget: usize, host_load_gbps: f64) -> Self {
        TieredPrefixCache {
            hbm: BTreeMap::new(),
            host: BTreeMap::new(),
            hbm_budget,
            host_budget,
            hbm_used: 0,
            host_used: 0,
            host_load_gbps,
            tick: 0,
            hbm_hits: 0,
            host_hits: 0,
            misses: 0,
            staging_ms: 0.0,
        }
    }

    fn staging_ms_for(&self, bytes: usize) -> f64 {
        bytes as f64 / (self.host_load_gbps * 1e9) * 1e3
    }

    /// Look up a prefix; on host hit or miss, the entry ends up resident
    /// in HBM. Returns the tier served from plus the extra latency (ms)
    /// this lookup incurred (0 for HBM hits).
    pub fn lookup(&mut self, key: PrefixKey, bytes: usize) -> (TierHit, f64) {
        self.tick += 1;
        if let Some(e) = self.hbm.get_mut(&key) {
            e.last_used = self.tick;
            self.hbm_hits += 1;
            return (TierHit::Hbm, 0.0);
        }
        if let Some(e) = self.host.remove(&key) {
            self.host_used -= e.bytes;
            self.host_hits += 1;
            let load_ms = self.staging_ms_for(e.bytes);
            self.staging_ms += load_ms;
            self.install_hbm(key, e.bytes);
            return (TierHit::Host, load_ms);
        }
        self.misses += 1;
        if bytes <= self.hbm_budget {
            self.install_hbm(key, bytes);
        }
        (TierHit::Miss, 0.0)
    }

    /// Install into HBM, demoting LRU HBM entries to host (flush charged).
    fn install_hbm(&mut self, key: PrefixKey, bytes: usize) {
        while self.hbm_used + bytes > self.hbm_budget {
            let lru = self
                .hbm
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("HBM over budget while empty");
            let e = self.hbm.remove(&lru).unwrap();
            self.hbm_used -= e.bytes;
            // Demote to host if it fits (flush cost charged).
            if e.bytes <= self.host_budget {
                self.staging_ms += self.staging_ms_for(e.bytes);
                while self.host_used + e.bytes > self.host_budget {
                    let hlru = self
                        .host
                        .iter()
                        .min_by_key(|(_, he)| he.last_used)
                        .map(|(k, _)| *k)
                        .expect("host over budget while empty");
                    let dropped = self.host.remove(&hlru).unwrap();
                    self.host_used -= dropped.bytes;
                }
                self.host_used += e.bytes;
                self.host.insert(lru, e);
            }
        }
        self.tick += 1;
        self.hbm_used += bytes;
        self.hbm.insert(key, Entry { bytes, last_used: self.tick });
    }

    pub fn hbm_len(&self) -> usize {
        self.hbm.len()
    }
    pub fn host_len(&self) -> usize {
        self.host.len()
    }

    /// Hit rate counting both tiers.
    pub fn combined_hit_rate(&self) -> f64 {
        let total = self.hbm_hits + self.host_hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        (self.hbm_hits + self.host_hits) as f64 / total as f64
    }

    pub fn hbm_hit_rate(&self) -> f64 {
        let total = self.hbm_hits + self.host_hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hbm_hits as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    const MB: usize = 1 << 20;

    #[test]
    fn hbm_hit_is_free_host_hit_pays_load() {
        let mut c = TieredPrefixCache::new(10 * MB, 100 * MB, 20.0);
        assert_eq!(c.lookup(PrefixKey::new(0, 1), 4 * MB).0, TierHit::Miss);
        assert_eq!(c.lookup(PrefixKey::new(0, 1), 4 * MB), (TierHit::Hbm, 0.0));
        // Fill HBM so (0,1) demotes to host.
        c.lookup(PrefixKey::new(0, 2), 4 * MB);
        c.lookup(PrefixKey::new(0, 3), 4 * MB); // evicts (0,1) -> host
        let (tier, load_ms) = c.lookup(PrefixKey::new(0, 1), 4 * MB);
        assert_eq!(tier, TierHit::Host);
        // 4 MiB at 20 GB/s ≈ 0.21 ms.
        assert!(load_ms > 0.1 && load_ms < 0.5, "load {load_ms}");
    }

    #[test]
    fn host_tier_extends_effective_capacity() {
        // 3 prefixes, HBM fits 2: with host tier the third round-robins
        // as host hits, never full misses after warmup.
        let mut c = TieredPrefixCache::new(8 * MB, 64 * MB, 20.0);
        for round in 0..5 {
            for p in 0..3 {
                let (tier, _) = c.lookup(PrefixKey::new(0, p), 4 * MB);
                if round > 0 {
                    assert_ne!(tier, TierHit::Miss, "round {round} prefix {p}");
                }
            }
        }
        assert!(c.combined_hit_rate() > 0.7);
        assert!(c.hbm_hit_rate() < c.combined_hit_rate());
    }

    #[test]
    fn without_host_tier_same_workload_misses() {
        let mut c = TieredPrefixCache::new(8 * MB, 0, 20.0);
        let mut misses = 0;
        for _round in 0..5 {
            for p in 0..3 {
                if c.lookup(PrefixKey::new(0, p), 4 * MB).0 == TierHit::Miss {
                    misses += 1;
                }
            }
        }
        assert!(misses >= 12, "LRU thrash expected, got {misses} misses");
    }

    #[test]
    fn staging_time_accumulates() {
        let mut c = TieredPrefixCache::new(8 * MB, 64 * MB, 20.0);
        for p in 0..3 {
            c.lookup(PrefixKey::new(0, p), 4 * MB);
        }
        let before = c.staging_ms;
        c.lookup(PrefixKey::new(0, 0), 4 * MB); // host hit -> load
        assert!(c.staging_ms > before);
    }

    #[test]
    fn prop_budgets_never_exceeded() {
        let cfg = prop::Config { cases: 48, ..Default::default() };
        prop::check(
            "tiered-budgets",
            &cfg,
            |r| (2 + r.below(16), 8 + r.below(64), r.next_u64()),
            |&(hbm_mb, host_mb, seed)| {
                let mut c =
                    TieredPrefixCache::new(hbm_mb * MB, host_mb * MB, 20.0);
                let mut rng = Rng::new(seed);
                for _ in 0..300 {
                    let key = PrefixKey::new(rng.below(3), rng.below(12));
                    let bytes = (1 + rng.below(4)) * MB;
                    c.lookup(key, bytes);
                    if c.hbm_used > c.hbm_budget {
                        return Err(format!(
                            "HBM {} > budget {}",
                            c.hbm_used, c.hbm_budget
                        ));
                    }
                    if c.host_used > c.host_budget {
                        return Err(format!(
                            "host {} > budget {}",
                            c.host_used, c.host_budget
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
