//! Serving metrics: request outcomes, SLO attainment, throughput Φ.
//!
//! Implements the paper's E2E performance accounting:
//! `Φ = min{I_t, n_p b_p / T_p, n_d b_d / T_d} / (n_p + n_d)` — throughput
//! per instance — plus TTFT/E2E percentile summaries and the success-rate
//! metric of Fig. 14a ("desired success rate is 100%, which implies no
//! requests break the timeout thresholds").

use crate::util::stats::Summary;

/// Outcome of one request.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    Completed {
        ttft_ms: f64,
        e2e_ms: f64,
        xfer_ms: f64,
        gen_tokens: usize,
    },
    /// Terminated by early intervention (gateway or prefill) — the request
    /// broke its TTFT threshold.
    TimedOut {
        waited_ms: f64,
    },
}

/// Aggregator over a run.
#[derive(Debug, Default)]
pub struct ServingReport {
    pub ttft: Summary,
    pub e2e: Summary,
    pub xfer: Summary,
    pub completed: usize,
    pub timed_out: usize,
    pub tokens_out: u64,
    /// Virtual duration covered (ms) — set by the driver at the end.
    pub duration_ms: f64,
    /// Instance counts, for per-instance throughput.
    pub n_prefill: usize,
    pub n_decode: usize,
}

impl ServingReport {
    pub fn new(n_prefill: usize, n_decode: usize) -> Self {
        ServingReport { n_prefill, n_decode, ..Default::default() }
    }

    pub fn record(&mut self, outcome: &Outcome) {
        match outcome {
            Outcome::Completed { ttft_ms, e2e_ms, xfer_ms, gen_tokens } => {
                self.completed += 1;
                self.ttft.add(*ttft_ms);
                self.e2e.add(*e2e_ms);
                self.xfer.add(*xfer_ms);
                self.tokens_out += *gen_tokens as u64;
            }
            Outcome::TimedOut { .. } => self.timed_out += 1,
        }
    }

    pub fn total(&self) -> usize {
        self.completed + self.timed_out
    }

    /// Fig. 14a's success rate.
    pub fn success_rate(&self) -> f64 {
        if self.total() == 0 {
            return 1.0;
        }
        self.completed as f64 / self.total() as f64
    }

    /// Completed requests per second.
    pub fn rps(&self) -> f64 {
        if self.duration_ms <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / (self.duration_ms / 1000.0)
    }

    /// The paper's Φ: requests/sec per instance.
    pub fn phi(&self) -> f64 {
        let n = self.n_prefill + self.n_decode;
        if n == 0 {
            return 0.0;
        }
        self.rps() / n as f64
    }

    /// Output tokens per second (decode goodput).
    pub fn tokens_per_sec(&self) -> f64 {
        if self.duration_ms <= 0.0 {
            return 0.0;
        }
        self.tokens_out as f64 / (self.duration_ms / 1000.0)
    }

    /// TTFT SLO attainment at a fixed threshold.
    pub fn ttft_slo_attainment(&mut self, threshold_ms: f64) -> f64 {
        // Timed-out requests count against the SLO.
        let ok = self.ttft.count() as f64 * self.ttft.fraction_le(threshold_ms);
        let total = self.total() as f64;
        if total == 0.0 {
            return 1.0;
        }
        ok / total
    }

    /// Mean T_p / E2E proportion — the ratio-adjustment alarm signal
    /// (Fig. 12c: "the proportion of T_p hints the P/D bottleneck").
    pub fn ttft_share_of_e2e(&self) -> f64 {
        if self.e2e.mean() <= 0.0 {
            return 0.0;
        }
        self.ttft.mean() / self.e2e.mean()
    }

    pub fn one_line(&mut self) -> String {
        format!(
            "n={} ok={:.1}% rps={:.2} phi={:.3} ttft(p50/p99)={:.0}/{:.0}ms \
             e2e(p50/p99)={:.0}/{:.0}ms tok/s={:.0}",
            self.total(),
            self.success_rate() * 100.0,
            self.rps(),
            self.phi(),
            self.ttft.p50(),
            self.ttft.p99(),
            self.e2e.p50(),
            self.e2e.p99(),
            self.tokens_per_sec()
        )
    }
}

/// The paper's bottleneck formula: Φ for given instance counts/capabilities
/// (requests/sec each) under input traffic `it_rps`.
pub fn phi_bottleneck(
    it_rps: f64,
    n_p: usize,
    prefill_rps_each: f64,
    n_d: usize,
    decode_rps_each: f64,
) -> f64 {
    let p_cap = n_p as f64 * prefill_rps_each;
    let d_cap = n_d as f64 * decode_rps_each;
    let served = it_rps.min(p_cap).min(d_cap);
    served / (n_p + n_d) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn done(ttft: f64, e2e: f64) -> Outcome {
        Outcome::Completed { ttft_ms: ttft, e2e_ms: e2e, xfer_ms: 5.0, gen_tokens: 100 }
    }

    #[test]
    fn success_rate_and_rps() {
        let mut r = ServingReport::new(2, 2);
        for _ in 0..9 {
            r.record(&done(100.0, 1000.0));
        }
        r.record(&Outcome::TimedOut { waited_ms: 600.0 });
        r.duration_ms = 10_000.0;
        assert!((r.success_rate() - 0.9).abs() < 1e-12);
        assert!((r.rps() - 0.9).abs() < 1e-12);
        assert!((r.phi() - 0.225).abs() < 1e-12);
        assert!((r.tokens_per_sec() - 90.0).abs() < 1e-9);
    }

    #[test]
    fn slo_attainment_counts_timeouts_against() {
        let mut r = ServingReport::new(1, 1);
        r.record(&done(100.0, 500.0));
        r.record(&done(400.0, 900.0));
        r.record(&Outcome::TimedOut { waited_ms: 700.0 });
        // Threshold 200: only the first completes in time; 1/3 attainment.
        assert!((r.ttft_slo_attainment(200.0) - 1.0 / 3.0).abs() < 1e-12);
        assert!((r.ttft_slo_attainment(500.0) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn ttft_share_signal() {
        let mut r = ServingReport::new(1, 1);
        r.record(&done(300.0, 1000.0));
        assert!((r.ttft_share_of_e2e() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn phi_bottleneck_takes_min() {
        // Prefill-bound.
        let phi = phi_bottleneck(100.0, 2, 10.0, 2, 50.0);
        assert!((phi - 20.0 / 4.0).abs() < 1e-12);
        // Traffic-bound.
        let phi2 = phi_bottleneck(5.0, 2, 10.0, 2, 50.0);
        assert!((phi2 - 5.0 / 4.0).abs() < 1e-12);
        // Decode-bound.
        let phi3 = phi_bottleneck(100.0, 4, 10.0, 1, 8.0);
        assert!((phi3 - 8.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn empty_report_sane() {
        let mut r = ServingReport::new(0, 0);
        assert_eq!(r.success_rate(), 1.0);
        assert_eq!(r.phi(), 0.0);
        assert_eq!(r.ttft_slo_attainment(100.0), 1.0);
    }
}
