//! D2D single-pull path benches — gather/pull/place cost scaling with
//! block count (`cargo bench --bench d2d [-- --fast]`).
//!
//! Guards the tentpole's data plane: gather and per-block placement carry
//! a per-block term (so halving the block size must not silently double
//! the hot-path cost), the single pull behaves like one bulk copy
//! regardless of how the sender's HBM was fragmented, the layer-wise
//! pipelined pull stays within a constant factor of the monolithic copy
//! (its reads coalesce), and the timing model's blocked / single-pull /
//! overlapped split stays pure arithmetic. Every run refreshes
//! `BENCH_d2d.json` at the repo root for `pdserve bench-diff`.

use pd_serve::bench::Bencher;
use pd_serve::kvcache::d2d::{
    place_into_blocks, AssemblyModel, D2dRegion, LayerBlocks, PipelinedPull,
};
use pd_serve::network::rdma::RdmaModel;
use pd_serve::util::prng::Rng;

/// 8 layers of `layer_bytes` shattered into `block_bytes` blocks, with a
/// deliberately ragged tail (last layer one byte short).
fn layers_at(block_bytes: usize, layer_bytes: usize, rng: &mut Rng) -> Vec<LayerBlocks> {
    (0..8)
        .map(|l| {
            let len = if l == 7 { layer_bytes - 1 } else { layer_bytes };
            let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            LayerBlocks::from_payload(&payload, block_bytes).unwrap()
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(0xD2D);
    let layer_bytes = 1 << 20; // 8 MiB total payload
    let total = 8.0 * layer_bytes as f64;

    // Data plane: the same payload at three fragmentation levels — the
    // per-block cost term is what block count scales.
    for &block in &[256 << 10, 64 << 10, 16 << 10] {
        let n_blocks = 8 * layer_bytes / block;
        b.group(&format!(
            "d2d data plane ({} KiB blocks, {n_blocks} blocks / 8 MiB)",
            block >> 10
        ));
        let layers = layers_at(block, layer_bytes, &mut rng);
        b.bench("gather into contiguous region", Some((total, "B")), || {
            D2dRegion::gather(&layers).unwrap().bytes()
        });
        let region = D2dRegion::gather(&layers).unwrap();
        b.bench("single pull (one read)", Some((total, "B")), || {
            region.pull().bytes()
        });
        let mut out: Vec<Vec<Vec<u8>>> = region
            .dir()
            .iter()
            .map(|&(_, len)| vec![Vec::new(); len.div_ceil(block)])
            .collect();
        b.bench("scatter-free place into blocks", Some((total, "B")), || {
            place_into_blocks(&region, block, &mut out).unwrap()
        });
    }

    // Layer-wise pipelined pull over the same payload: the eager receiver
    // reads each of the 8 layers as it is staged. Benched against the one
    // contiguous pull above — the pipeline's reads coalesce, so the byte
    // volume is identical and the delta is per-read bookkeeping only.
    b.group("d2d pipelined pull (8 layers / 8 MiB)");
    let layers = layers_at(256 << 10, layer_bytes, &mut rng);
    let region = D2dRegion::gather(&layers).unwrap();
    let src = region.as_bytes();
    let dir: Vec<(usize, usize)> = region.dir().to_vec();
    b.bench("eager layer-wise pull (8 reads)", Some((total, "B")), || {
        let mut plan = PipelinedPull::new(dir.clone()).unwrap();
        for l in 0..dir.len() {
            plan.stage(l).unwrap();
            plan.pull_ready(src).unwrap();
        }
        plan.finish().unwrap().bytes()
    });
    b.bench("lazy pipelined pull (1 coalesced read)", Some((total, "B")), || {
        let mut plan = PipelinedPull::new(dir.clone()).unwrap();
        for l in 0..dir.len() {
            plan.stage(l).unwrap();
        }
        plan.pull_ready(src).unwrap();
        plan.finish().unwrap().bytes()
    });

    b.group("transfer-time model (420 MiB per device)");
    let m = RdmaModel::default();
    let bytes = 420 << 20;
    for &block in &[16 << 10, 256 << 10, 1600 << 10] {
        let name = format!("blocked_cost at {} KiB blocks", block >> 10);
        b.bench(&name, Some((1.0, "op")), || {
            m.blocked_cost(bytes, block, 3, 2).total_us()
        });
    }
    b.bench("single_pull_cost", Some((1.0, "op")), || {
        m.single_pull_cost(bytes, 3, 2).total_us()
    });
    // 40 layers hidden behind 100 ms of prefill compute — the tentpole's
    // closed form must stay as cheap as the single-pull arithmetic.
    b.bench("overlapped_cost (40 layers)", Some((1.0, "op")), || {
        m.overlapped_cost(bytes, 40, 100_000.0, 3, 2).exposed_us
    });

    b.group("assembly cost model");
    let asm = AssemblyModel::default();
    for &blocks in &[64usize, 1024, 16384] {
        let name = format!("gather_us / place_blocked_us at {blocks} blocks");
        b.bench(&name, Some((1.0, "op")), || {
            asm.gather_us(bytes, blocks) + asm.place_blocked_us(bytes, blocks)
        });
    }

    println!("\n{}", b.finish());
    match b.write_json_report("d2d") {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("BENCH_d2d.json not written: {e}"),
    }
}
