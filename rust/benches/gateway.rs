//! L3 hot-path benches: gateway forwarding decisions.
//!
//! The forwarding decision runs once per request per probe round — it must
//! be microseconds. Covers: SSE registry updates, route-policy candidate
//! ordering (the unified routing layer), the full probe, and the baseline
//! scheduler pick for comparison. `cargo bench --bench gateway [-- --fast]`.

use pd_serve::bench::Bencher;
use pd_serve::gateway::baseline::StaleQueueScheduler;
use pd_serve::gateway::forward::OnDemandForwarder;
use pd_serve::gateway::sse::SseRegistry;
use pd_serve::serving::router::{RouteKind, RouteRequest};
use pd_serve::util::prng::Rng;

fn main() {
    let mut b = Bencher::new();

    for &n_p in &[8usize, 64, 512] {
        b.group(&format!("gateway ({n_p} prefills)"));

        let mut sse = SseRegistry::new(0..n_p as u32);
        let mut rng = Rng::new(1);
        for _ in 0..n_p * 3 {
            sse.open(rng.below(n_p) as u32);
        }

        b.bench("sse open+close", Some((1.0, "op")), || {
            let e = rng.below(n_p) as u32;
            sse.open(e);
            sse.close(e);
        });

        let mut ll = RouteKind::LeastLoaded.build();
        b.bench("least-SSE ordering (salted policy)", Some((1.0, "op")), || {
            ll.order(&sse.snapshot(), &RouteRequest::opaque(), rng.next_u64())
                .len()
        });

        let forwarder = OnDemandForwarder::new(4, 5.0);
        let busy_mask: Vec<bool> = (0..n_p).map(|i| i % 3 != 0).collect();
        b.bench("on-demand probe (4 candidates)", Some((1.0, "req")), || {
            forwarder.probe(
                ll.as_mut(),
                &sse,
                &RouteRequest::opaque(),
                rng.next_u64(),
                0.0,
                1e9,
                |e| !busy_mask[e as usize],
            )
        });

        let mut sched = StaleQueueScheduler::new(n_p, 100.0);
        for i in 0..n_p {
            sched.maybe_report(i, rng.below(8192), 0.0);
        }
        b.bench("baseline shortest-queue pick", Some((1.0, "req")), || {
            sched.pick_shortest(1024, true)
        });
    }

    println!("\n{}", b.finish());
}
