//! Routing-layer benches: per-decision policy cost and the bucketed
//! prefix-cache lookup guard.
//!
//! Two regression anchors:
//! - `order()` runs once per request per probe round for every policy —
//!   prefix affinity must stay within the same order of magnitude as the
//!   plain least-SSE sort.
//! - `PrefixCache::lookup` runs per candidate per batch slot in the
//!   simulator's admission loop; the first-token-bucket index must keep it
//!   near-flat as the number of live prefixes grows (the pre-index linear
//!   scan made the hot loop quadratic). `cargo bench --bench router -- --fast`.

use pd_serve::bench::Bencher;
use pd_serve::cluster::prefix::PrefixCache;
use pd_serve::serving::router::{RouteKind, RouteRequest};

fn main() {
    let mut b = Bencher::new();

    b.group("route policy — order() over 64 entrances");
    let snap: Vec<(u32, usize)> = (0..64u32).map(|e| (e, (e as usize * 7) % 5)).collect();
    for kind in [
        RouteKind::Random,
        RouteKind::RoundRobin,
        RouteKind::LeastLoaded,
        RouteKind::PrefixAffinity,
    ] {
        let mut policy = kind.build();
        let mut salt = 0u64;
        b.bench(kind.name(), Some((1.0, "decision")), || {
            salt = salt.wrapping_add(0x9E37_79B9);
            let req = RouteRequest { prefix_hash: Some(salt & 0x3F) };
            let order = policy.order(&snap, &req, salt);
            policy.placed(order[0], &req);
            order[0]
        });
    }

    b.group("prefix cache — 64-token lookup vs live-prefix count");
    for &n in &[64usize, 512, 4096] {
        // Budget sized to hold everything: this isolates lookup cost.
        let mut cache = PrefixCache::new(n * 64 * 2, 1);
        let mut probes: Vec<Vec<i32>> = Vec::with_capacity(n);
        for i in 0..n {
            // 251 distinct first tokens: buckets stay shallow even at 4k
            // entries, which is exactly the point of the index; the tail
            // makes every prefix distinct.
            let prefix: Vec<i32> = (0..64i32)
                .map(|j| if j == 0 { (i % 251) as i32 } else { i as i32 * 64 + j })
                .collect();
            cache.insert(&prefix);
            probes.push(prefix);
        }
        let mut i = 0;
        b.bench(&format!("{n} live prefixes"), Some((1.0, "lookup")), || {
            i = (i + 1) % probes.len();
            cache.lookup(&probes[i])
        });
    }

    println!("\n{}", b.finish());
}
