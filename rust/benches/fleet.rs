//! Fleet-loop benches: how fast the closed-loop simulator turns one
//! compressed tidal day, dynamic vs frozen control — the regression anchor
//! for the `serving::fleet` event path (shared queue + per-group sims +
//! control ticks). `cargo bench --bench fleet -- --fast` for CI.

use pd_serve::bench::Bencher;
use pd_serve::serving::fleet::{FleetConfig, FleetSim};

fn day(adjust: bool, scale: bool) -> FleetConfig {
    FleetConfig {
        scenes: vec![2, 5],
        peak_total_rps: 20.0,
        ms_per_hour: 1_000.0,
        control_period_ms: 1_000.0,
        slice_ms: 500.0,
        adjust_ratio: adjust,
        scale_groups: scale,
        seed: 0xBE7C,
        ..Default::default()
    }
}

fn main() {
    let mut b = Bencher::new();

    b.group("fleet — one compressed tidal day (2 scenes)");
    for (name, adjust, scale) in [
        ("closed loop (ratio + scaling)", true, true),
        ("ratio only", true, false),
        ("frozen (static baseline)", false, false),
    ] {
        let cfg = day(adjust, scale);
        b.bench(name, Some((1.0, "day")), || {
            FleetSim::new(cfg.clone()).run().completed
        });
    }

    b.group("fleet — control-plane overhead vs fleet width");
    for scenes in [vec![2usize], vec![0, 2, 5], vec![0, 1, 2, 3, 4, 5]] {
        let mut cfg = day(true, true);
        let n = scenes.len();
        cfg.scenes = scenes;
        let name = format!("{n} scene groups");
        b.bench(&name, Some((n as f64, "group-day")), || {
            FleetSim::new(cfg.clone()).run().completed
        });
    }

    println!("\n{}", b.finish());
}
