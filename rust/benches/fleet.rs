//! Fleet-loop benches: how fast the closed-loop simulator turns one
//! compressed tidal day, dynamic vs frozen control — the regression anchor
//! for the `serving::fleet` event path (shared queue + per-group sims +
//! control ticks) and for the scene-sharded parallel day.
//! `cargo bench --bench fleet -- --fast` for CI; every run refreshes
//! `BENCH_fleet.json` at the repo root for `pdserve bench-diff`.

use pd_serve::bench::Bencher;
use pd_serve::serving::fleet::{FleetConfig, FleetSim};
use pd_serve::serving::shard::run_sharded;

fn day(adjust: bool, scale: bool) -> FleetConfig {
    FleetConfig {
        scenes: vec![2, 5],
        peak_total_rps: 20.0,
        ms_per_hour: 1_000.0,
        control_period_ms: 1_000.0,
        slice_ms: 500.0,
        adjust_ratio: adjust,
        scale_groups: scale,
        seed: 0xBE7C,
        ..Default::default()
    }
}

fn main() {
    let mut b = Bencher::new();

    b.group("fleet — one compressed tidal day (2 scenes)");
    for (name, adjust, scale) in [
        ("closed loop (ratio + scaling)", true, true),
        ("ratio only", true, false),
        ("frozen (static baseline)", false, false),
    ] {
        let cfg = day(adjust, scale);
        let params = format!("adjust={adjust} scale={scale} scenes=2 peak=20");
        b.bench_case(name, &params, Some((1.0, "day")), || {
            FleetSim::new(cfg.clone()).run().completed
        });
    }

    b.group("fleet — control-plane overhead vs fleet width");
    for scenes in [vec![2usize], vec![0, 2, 5], vec![0, 1, 2, 3, 4, 5]] {
        let mut cfg = day(true, true);
        let n = scenes.len();
        cfg.scenes = scenes;
        let name = format!("{n} scene groups");
        b.bench_case(&name, &format!("scenes={n} peak=20"), Some((n as f64, "group-day")), || {
            FleetSim::new(cfg.clone()).run().completed
        });
    }

    // The scene-sharded day: the same 6-scene workload on 1 worker vs all
    // cores. Both runs produce byte-identical reports (the determinism
    // oracle); the delta is pure wall clock.
    b.group("fleet — scene-sharded day (6 scenes)");
    let mut wide = day(true, true);
    wide.scenes = vec![0, 1, 2, 3, 4, 5];
    for workers in [1usize, 4] {
        let cfg = wide.clone();
        let name = format!("--workers {workers}");
        b.bench_case(&name, &format!("scenes=6 peak=20 workers={workers}"), Some((6.0, "group-day")), || {
            run_sharded(cfg.clone(), workers).completed
        });
    }

    println!("\n{}", b.finish());
    match b.write_json_report("fleet") {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("BENCH_fleet.json not written: {e}"),
    }
}
