//! HBM substrate benches: PageAttention block allocator, prefix cache,
//! send-buffer pool. These sit on every admission/completion, so they must
//! stay well under a microsecond. `cargo bench --bench allocator`.

use pd_serve::bench::Bencher;
use pd_serve::cluster::hbm::BlockAllocator;
use pd_serve::cluster::prefix::PrefixCache;
use pd_serve::kvcache::buffer::SendBufferPool;
use pd_serve::util::prng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(3);

    b.group("BlockAllocator (12 GiB budget, 64 KiB blocks)");
    let mut alloc = BlockAllocator::new(12 << 30, 64 << 10);
    b.bench("allocate+release (1.6 MiB seq)", Some((1.0, "seq")), || {
        let h = alloc.allocate(1600 << 10).unwrap();
        alloc.release(h).unwrap()
    });
    let grow_h = alloc.allocate(64 << 10).unwrap();
    let mut cur = 64 << 10;
    b.bench("grow by one token (4 KiB)", Some((1.0, "tok")), || {
        alloc.grow(grow_h, cur, 4096).unwrap();
        cur += 4096;
        if cur > (1 << 30) {
            alloc.release(grow_h).unwrap();
            let _ = alloc.allocate(64 << 10).unwrap();
            cur = 64 << 10;
        }
    });

    b.group("PrefixCache (12 GiB, 800 KiB/token)");
    let mut cache = PrefixCache::new(12 << 30, 800 * 1024);
    let prefixes: Vec<Vec<i32>> = (0..16)
        .map(|p| (0..1024).map(|i| ((p * 7 + i) % 256) as i32).collect())
        .collect();
    for p in &prefixes {
        cache.insert(p);
    }
    let mut prompt = prefixes[7].clone();
    prompt.extend_from_slice(&[9, 9, 9, 9]);
    b.bench("lookup (16 entries, 1k-token prompt)", Some((1.0, "req")), || {
        cache.lookup(&prompt)
    });
    b.bench("insert (duplicate fast path)", Some((1.0, "op")), || {
        cache.insert(&prefixes[3])
    });

    b.group("SendBufferPool (bp=4, 96 KiB buffers)");
    let mut pool = SendBufferPool::new(4, 98_304 / 4);
    let data = vec![0.5f32; 98_304 / 4];
    b.bench("acquire+write+release", Some((data.len() as f64 * 4.0, "B")), || {
        let id = pool.acquire().unwrap();
        pool.write(id, &data).unwrap();
        pool.release(id).unwrap()
    });

    // Keep the RNG alive so the allocator loop above can't be const-folded.
    std::hint::black_box(rng.next_u64());
    println!("\n{}", b.finish());
}
