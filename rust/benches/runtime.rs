//! Real-model runtime benches: the request-path costs of the PJRT
//! executables (prefill per bucket, decode iteration, operator
//! RecvScatter) plus the host transfer path (byte extraction + function
//! scatter). Requires `make artifacts`; skips gracefully otherwise.
//! `cargo bench --bench runtime [-- --fast]`.

use pd_serve::bench::Bencher;
use pd_serve::runtime::model::{bytemuck_cast, bytes_as_f32};
use pd_serve::runtime::{tokenizer, ServingRuntime};

fn main() {
    let dir = ["artifacts", "../artifacts"]
        .iter()
        .find(|d| std::path::Path::new(&format!("{d}/meta.json")).exists());
    let Some(dir) = dir else {
        eprintln!("skipping runtime benches: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let rt = match ServingRuntime::load(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping runtime benches: {e:#}");
            return;
        }
    };
    let mut b = Bencher::new();

    b.group("prefill executables");
    let short = tokenizer::encode("short prompt");
    let long: Vec<i32> = (0..60).map(|i| (i * 3 + 7) % 256).collect();
    b.bench("prefill bucket 16 (12 tokens)", Some((12.0, "tok")), || {
        rt.prefill(&short, 0, None).unwrap().logits.len()
    });
    b.bench("prefill bucket 64 (60 tokens)", Some((60.0, "tok")), || {
        rt.prefill(&long, 0, None).unwrap().logits.len()
    });
    let chunk1 = rt.prefill(&long[..16], 0, None).unwrap();
    b.bench("chunked continuation (16 @ start=16)", Some((16.0, "tok")), || {
        rt.prefill(&long[..16], 16, Some(&chunk1.cache)).unwrap().logits.len()
    });

    b.group("transfer path (384 KiB KVCache)");
    let out = rt.prefill(&long, 0, None).unwrap();
    b.bench("cache -> bytes -> cache (host)", Some((out.cache.len() as f64 * 4.0, "B")), || {
        let bytes = bytemuck_cast(&out.cache);
        bytes_as_f32(bytes).len()
    });
    let mut handle = rt.new_decode_handle().unwrap();
    b.bench("operator RecvScatter (PJRT)", Some((out.cache.len() as f64 * 4.0, "B")), || {
        rt.scatter_device(&mut handle, 0, &out.cache).unwrap()
    });

    b.group("decode");
    handle.lens[0] = long.len() as i32;
    handle.active[0] = true;
    let mut tok = vec![0i32; handle.batch()];
    tok[0] = rt.argmax_row(&out.logits, 0);
    b.bench("decode iteration (batch 4)", Some((4.0, "tok")), || {
        // Keep lens bounded: reset periodically.
        if handle.lens[0] as usize >= rt.meta.max_len - 2 {
            handle.lens[0] = long.len() as i32;
        }
        let logits = rt.decode_step(&mut handle, &tok).unwrap();
        tok[0] = rt.argmax_row(&logits, 0);
    });
    let logits = rt.decode_step(&mut handle, &tok).unwrap();
    b.bench("argmax over vocab row", Some((1.0, "op")), || {
        rt.argmax_row(&logits, 0)
    });

    println!("\n{}", b.finish());
}
