//! KVCache transfer path benches (paper Fig. 4 / 14c hot path).
//!
//! Covers: the RDMA timing model itself, the *function* RecvScatter
//! (host byte scatter — the data-plane cost the receiver actually pays),
//! block gather/scatter, and the ECMP/spray spine assignment.
//! `cargo bench --bench transfer [-- --fast]`.

use pd_serve::bench::Bencher;
use pd_serve::kvcache::layout::KvLayout;
use pd_serve::kvcache::scatter::{
    gather_from_blocks, gather_from_decode, scatter_into_blocks, scatter_into_decode,
};
use pd_serve::network::rdma::RdmaModel;
use pd_serve::network::route;
use pd_serve::util::prng::Rng;

fn main() {
    let mut b = Bencher::new();
    let m = RdmaModel::default();

    b.group("rdma timing model");
    b.bench("blocked_us (420 MiB / 1.6 MiB blocks)", Some((1.0, "op")), || {
        m.blocked_us(420 << 20, 1600 << 10, 3, 2)
    });
    b.bench("contiguous_us (420 MiB)", Some((1.0, "op")), || {
        m.contiguous_us(420 << 20, 3, 2)
    });

    b.group("RecvScatter (serving model: L4 H4 M96 hd32, B4)");
    let layout = KvLayout::new(4, 4, 96, 32, 4);
    let mut rng = Rng::new(2);
    let payload: Vec<f32> = (0..layout.prefill_elems()).map(|_| rng.f64() as f32).collect();
    let mut mirror = vec![0f32; layout.decode_elems()];
    let shape = vec![4usize, 2, 4, 4, 96, 32];
    let bytes = layout.prefill_bytes() as f64;
    b.bench("scatter_into_decode", Some((bytes, "B")), || {
        scatter_into_decode(&mut mirror, &payload, &shape, 1).unwrap()
    });
    b.bench("gather_from_decode", Some((bytes, "B")), || {
        gather_from_decode(&mirror, &shape, 1).unwrap().len()
    });

    b.group("block scatter (64 KiB blocks)");
    let wire: Vec<u8> = (0..(4 << 20)).map(|i| i as u8).collect();
    let mut blocks = vec![Vec::new(); wire.len().div_ceil(64 << 10)];
    b.bench("scatter_into_blocks (4 MiB)", Some((wire.len() as f64, "B")), || {
        scatter_into_blocks(&wire, &mut blocks, 64 << 10).unwrap()
    });
    b.bench("gather_from_blocks (4 MiB)", Some((wire.len() as f64, "B")), || {
        gather_from_blocks(&blocks, wire.len()).unwrap().len()
    });

    b.group("spine assignment (8 sub-transfers / 8 spines)");
    let mut flow = 0u64;
    b.bench("ECMP", Some((1.0, "move")), || {
        flow += 1;
        route::assign_ecmp(0, 1, flow, 8, 8).len()
    });
    b.bench("path-sprayed", Some((1.0, "move")), || {
        flow += 1;
        route::assign_sprayed(flow, 8, 8).len()
    });

    println!("\n{}", b.finish());
}
