//! Ablation sweeps over the design choices DESIGN.md calls out:
//! gateway retry-candidate count, prefill batch window, arrival burstiness
//! and the retrieval-queue depth. Each point runs the Fig.-14a scenario
//! and reports the achieved success rate alongside the wall time of the
//! sweep point. `cargo bench --bench ablation [-- --fast]`.

use pd_serve::bench::Bencher;
use pd_serve::serving::sim::{Policy, SimConfig, Simulation, WorkloadKind};
use pd_serve::workload::Scenario;

fn scenario() -> Scenario {
    Scenario {
        name: "ablate", service: "svc",
        prompt_mean: 2500.0, prompt_cv: 0.9,
        n_prefixes: 8, prefix_frac: 0.5,
        gen_mean: 60.0, gen_cv: 0.5, weight: 1.0,
    }
}

fn base_cfg() -> SimConfig {
    SimConfig {
        n_p: 6,
        n_d: 3,
        policy: Policy::OnDemand,
        scenarios: vec![scenario()],
        only_scenario: Some(0),
        workload: WorkloadKind::Open { rps: 6.0, duration_ms: 30_000.0 },
        seed: 0xAB1A7E,
        ..Default::default()
    }
}

fn main() {
    let mut b = Bencher::new();

    b.group("retry candidates (on-demand probe breadth)");
    for cand in [1usize, 2, 4, 6] {
        let mut cfg = base_cfg();
        cfg.serving.retry_candidates = cand;
        let ok = Simulation::run(cfg.clone()).report.success_rate();
        b.bench(
            &format!("candidates={cand} (success {:.1}%)", ok * 100.0),
            Some((1.0, "run")),
            || Simulation::run(cfg.clone()).report.completed,
        );
    }

    b.group("prefill batch window");
    for window in [1.0f64, 6.0, 20.0, 60.0] {
        let mut cfg = base_cfg();
        cfg.batch_window_ms = window;
        let ok = Simulation::run(cfg.clone()).report.success_rate();
        b.bench(
            &format!("window={window}ms (success {:.1}%)", ok * 100.0),
            Some((1.0, "run")),
            || Simulation::run(cfg.clone()).report.completed,
        );
    }

    b.group("arrival burstiness");
    for burst in [1usize, 4, 8] {
        let mut cfg = base_cfg();
        cfg.burst = burst;
        let ok = Simulation::run(cfg.clone()).report.success_rate();
        b.bench(
            &format!("burst={burst} (success {:.1}%)", ok * 100.0),
            Some((1.0, "run")),
            || Simulation::run(cfg.clone()).report.completed,
        );
    }

    b.group("retrieval queue depth (async retrieval, §3.6)");
    for depth in [0usize, 2, 8] {
        let mut cfg = base_cfg();
        cfg.serving.retrieval_queue = depth;
        let ok = Simulation::run(cfg.clone()).report.success_rate();
        b.bench(
            &format!("depth={depth} (success {:.1}%)", ok * 100.0),
            Some((1.0, "run")),
            || Simulation::run(cfg.clone()).report.completed,
        );
    }

    println!("\n{}", b.finish());
}
