//! End-to-end simulation benches — one per evaluation table/figure family.
//!
//! These measure how fast the *simulator* regenerates each paper result
//! (events/sec of the discrete-event core) and double as regression
//! anchors for the figures themselves: each bench runs the exact config a
//! figure uses. `cargo bench --bench e2e_sim -- --fast` for CI.

use pd_serve::bench::Bencher;
use pd_serve::serving::sim::{
    Policy, SimConfig, Simulation, TransferDiscipline, WorkloadKind,
};
use pd_serve::workload::Scenario;

fn fig14_scenario() -> Scenario {
    Scenario {
        name: "fig14", service: "svc",
        prompt_mean: 2500.0, prompt_cv: 0.9,
        n_prefixes: 8, prefix_frac: 0.5,
        gen_mean: 60.0, gen_cv: 0.5, weight: 1.0,
    }
}

fn main() {
    let mut b = Bencher::new();

    b.group("Fig 12d/13a — closed-loop ratio sweep point");
    let closed = SimConfig {
        n_p: 4,
        n_d: 4,
        only_scenario: Some(2),
        workload: WorkloadKind::Closed { concurrency: 48, requests: 200 },
        ..Default::default()
    };
    b.bench("closed loop, 200 requests", Some((200.0, "req")), || {
        Simulation::run(closed.clone()).report.completed
    });

    b.group("Fig 14a — open-loop policy comparison point");
    for (name, policy) in [
        ("baseline @ 4A", Policy::BaselineQueue),
        ("on-demand @ 4A", Policy::OnDemand),
    ] {
        let cfg = SimConfig {
            n_p: 6,
            n_d: 3,
            policy,
            scenarios: vec![fig14_scenario()],
            only_scenario: Some(0),
            workload: WorkloadKind::Open { rps: 8.0, duration_ms: 20_000.0 },
            ..Default::default()
        };
        b.bench(name, Some((1.0, "run")), || {
            Simulation::run(cfg.clone()).report.total()
        });
    }

    b.group("Fig 14c — transfer discipline point");
    for (name, transfer) in [
        ("blocked", TransferDiscipline::Blocked),
        ("contiguous", TransferDiscipline::Contiguous),
    ] {
        let cfg = SimConfig {
            n_p: 4,
            n_d: 4,
            transfer,
            only_scenario: Some(1),
            workload: WorkloadKind::Closed { concurrency: 24, requests: 150 },
            ..Default::default()
        };
        b.bench(name, Some((150.0, "req")), || {
            Simulation::run(cfg.clone()).report.completed
        });
    }

    println!("\n{}", b.finish());
}
