//! End-to-end simulation benches — one per evaluation table/figure family.
//!
//! These measure how fast the *simulator* regenerates each paper result
//! (events/sec of the discrete-event core) and double as regression
//! anchors for the figures themselves: each bench runs the exact config a
//! figure uses. The `hotloop` group pins the flattened hot-loop
//! primitives against their pre-flattening shapes so the win stays
//! measured, not asserted. `cargo bench --bench e2e_sim -- --fast` for
//! CI; every run refreshes `BENCH_sim.json` at the repo root for
//! `pdserve bench-diff`.

use std::collections::BTreeMap;
use std::rc::Rc;

use pd_serve::bench::Bencher;
use pd_serve::serving::sim::{
    Policy, SimConfig, Simulation, TransferDiscipline, WorkloadKind,
};
use pd_serve::workload::Scenario;

fn fig14_scenario() -> Scenario {
    Scenario {
        name: "fig14", service: "svc",
        prompt_mean: 2500.0, prompt_cv: 0.9,
        n_prefixes: 8, prefix_frac: 0.5,
        gen_mean: 60.0, gen_cv: 0.5, weight: 1.0,
    }
}

/// The `hotloop` group: paired before/after microbenches for each
/// flattening in `serving::sim`, on synthetic state shaped like a busy
/// decode pool. "(before)" cases reproduce the replaced implementation
/// so `BENCH_sim.json` carries the comparison forward.
fn hotloop(b: &mut Bencher) {
    b.group("hotloop — pool scans");
    let active: Vec<u64> = (0..4096u64).collect();
    // Every 64th request completes this decode iteration, in scan order
    // (ascending), exactly like `on_decode_iter`'s completion list.
    let completed: Vec<u64> = (0..4096u64).step_by(64).collect();
    let params = format!("active={} completed={}", active.len(), completed.len());
    b.bench_case("per-id retain scan (before)", &params, Some((completed.len() as f64, "removal")), || {
        let mut v = active.clone();
        for &id in &completed {
            v.retain(|&x| x != id);
        }
        v.len()
    });
    b.bench_case("single merge-retain (after)", &params, Some((completed.len() as f64, "removal")), || {
        let mut v = active.clone();
        let mut ci = 0;
        v.retain(|&x| {
            if ci < completed.len() && completed[ci] == x {
                ci += 1;
                false
            } else {
                true
            }
        });
        v.len()
    });

    b.group("hotloop — shared-prefix handles");
    const N_PREFIXES: usize = 8;
    const PREFIX_LEN: usize = 2048;
    const REQUESTS: usize = 4096;
    let params = format!("prefixes={N_PREFIXES} len={PREFIX_LEN} reqs={REQUESTS}");
    b.bench_case("Rc<Vec<i32>> per request (before)", &params, Some((REQUESTS as f64, "req")), || {
        // The replaced shape: a memo of Rc handles, one clone per request
        // held for the request's lifetime (dropped at batch end here).
        let mut memo: BTreeMap<usize, Rc<Vec<i32>>> = BTreeMap::new();
        let mut held: Vec<Rc<Vec<i32>>> = Vec::with_capacity(REQUESTS);
        let mut sum = 0i64;
        for r in 0..REQUESTS {
            let pid = r % N_PREFIXES;
            let toks = memo
                .entry(pid)
                .or_insert_with(|| {
                    Rc::new((0..PREFIX_LEN as i32).map(|t| (pid as i32) ^ t).collect())
                })
                .clone();
            sum += toks[r % PREFIX_LEN] as i64;
            held.push(toks);
        }
        std::hint::black_box(held.len());
        sum
    });
    b.bench_case("interned arena ids (after)", &params, Some((REQUESTS as f64, "req")), || {
        // The landed shape: requests hold a u32 into a scene-level arena.
        let mut arena: Vec<Vec<i32>> = Vec::new();
        let mut memo: BTreeMap<usize, u32> = BTreeMap::new();
        let mut held: Vec<u32> = Vec::with_capacity(REQUESTS);
        let mut sum = 0i64;
        for r in 0..REQUESTS {
            let pid = r % N_PREFIXES;
            let idx = *memo.entry(pid).or_insert_with(|| {
                arena.push((0..PREFIX_LEN as i32).map(|t| (pid as i32) ^ t).collect());
                (arena.len() - 1) as u32
            });
            sum += arena[idx as usize][r % PREFIX_LEN] as i64;
            held.push(idx);
        }
        std::hint::black_box(held.len());
        sum
    });

    b.group("hotloop — window stats");
    // `take_window` is the per-control-tick read on every group; after
    // flattening it is a plain Copy + reset, no allocation.
    let mut sim = Simulation::external(SimConfig {
        n_p: 2,
        n_d: 2,
        only_scenario: Some(2),
        workload: WorkloadKind::Closed { concurrency: 1, requests: 1 },
        ..Default::default()
    });
    b.bench_case("take_window (copy, allocation-free)", "n_p=2 n_d=2", None, || {
        sim.take_window().xfers
    });
}

fn main() {
    let mut b = Bencher::new();

    b.group("Fig 12d/13a — closed-loop ratio sweep point");
    let closed = SimConfig {
        n_p: 4,
        n_d: 4,
        only_scenario: Some(2),
        workload: WorkloadKind::Closed { concurrency: 48, requests: 200 },
        ..Default::default()
    };
    b.bench_case("closed loop, 200 requests", "n_p=4 n_d=4 conc=48", Some((200.0, "req")), || {
        Simulation::run(closed.clone()).report.completed
    });

    b.group("Fig 14a — open-loop policy comparison point");
    for (name, policy) in [
        ("baseline @ 4A", Policy::BaselineQueue),
        ("on-demand @ 4A", Policy::OnDemand),
    ] {
        let cfg = SimConfig {
            n_p: 6,
            n_d: 3,
            policy,
            scenarios: vec![fig14_scenario()],
            only_scenario: Some(0),
            workload: WorkloadKind::Open { rps: 8.0, duration_ms: 20_000.0 },
            ..Default::default()
        };
        b.bench_case(name, "n_p=6 n_d=3 rps=8", Some((1.0, "run")), || {
            Simulation::run(cfg.clone()).report.total()
        });
    }

    b.group("Fig 14c — transfer discipline point");
    for (name, transfer) in [
        ("blocked", TransferDiscipline::Blocked),
        ("contiguous", TransferDiscipline::Contiguous),
    ] {
        let cfg = SimConfig {
            n_p: 4,
            n_d: 4,
            transfer,
            only_scenario: Some(1),
            workload: WorkloadKind::Closed { concurrency: 24, requests: 150 },
            ..Default::default()
        };
        b.bench_case(name, "n_p=4 n_d=4 conc=24", Some((150.0, "req")), || {
            Simulation::run(cfg.clone()).report.completed
        });
    }

    hotloop(&mut b);

    println!("\n{}", b.finish());
    match b.write_json_report("sim") {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("BENCH_sim.json not written: {e}"),
    }
}
