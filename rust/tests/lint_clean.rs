//! The tree lints clean: `pdserve lint` over this crate's own sources
//! must report zero errors against the committed ratchet baseline.
//!
//! This is the same invocation CI runs (`pdserve lint --json`); keeping it
//! as an integration test means a plain `cargo test` catches a regression
//! before the workflow does.

use std::path::Path;

use pd_serve::analysis::rules::{Severity, UNWRAP_BUDGET};
use pd_serve::analysis::{lint_tree, LintOptions, DEFAULT_BASELINE, DEFAULT_SRC};

fn report() -> pd_serve::analysis::LintReport {
    lint_tree(&LintOptions {
        src_dir: Path::new(DEFAULT_SRC),
        baseline_path: Path::new(DEFAULT_BASELINE),
    })
    .expect("lint over the crate's own sources")
}

#[test]
fn crate_sources_lint_clean_at_zero_errors() {
    let report = report();
    assert!(report.files_scanned > 20, "scanned {} files", report.files_scanned);
    let errors: Vec<String> = report
        .findings
        .iter()
        .filter(|f| f.severity == Severity::Error)
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(errors.is_empty(), "lint errors:\n{}", errors.join("\n"));
}

#[test]
fn unwrap_ratchet_baseline_is_not_stale() {
    // Every path in lint.baseline must still exist in the tree — a stale
    // entry means a file was renamed or deleted without regenerating the
    // baseline. (Under-budget notes are tolerated here; they only ask for
    // a tightening, which `--write-baseline` performs.)
    let stale: Vec<String> = report()
        .findings
        .iter()
        .filter(|f| f.rule == UNWRAP_BUDGET && f.message.contains("was not scanned"))
        .map(|f| f.file.clone())
        .collect();
    assert!(stale.is_empty(), "stale baseline entries: {}", stale.join(", "));
}
