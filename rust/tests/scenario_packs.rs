//! Data-driven golden suite over the committed scenario packs.
//!
//! Every `scenarios/*.toml` is discovered, parsed fail-fast, run through
//! the scene-sharded day at `--workers 1` **and** `--workers 4` (the two
//! reports must be byte-identical — the sharding oracle), self-checked
//! against its own `[[assert]]` rows, and byte-compared against its
//! committed golden report under `scenarios/goldens/`.
//!
//! Bless flow: a *missing* golden is written in place with a loud note
//! (commit it — first run in a fresh build environment bootstraps the
//! snapshots); a *mismatching* golden fails with a first-difference diff
//! hint and the explicit re-bless instruction:
//!
//! ```text
//! UPDATE_GOLDENS=1 cargo test --test scenario_packs
//! ```
//!
//! The property tests at the bottom extend the same contract to *random*
//! in-range packs: serialize → re-parse → equal struct, and workers-1 vs
//! workers-4 byte identity on the compiled day.

use std::fs;
use std::path::{Path, PathBuf};

use pd_serve::coordinator::mlops::PlannerKind;
use pd_serve::serving::router::RouteKind;
use pd_serve::serving::scenario::{
    golden_diff_hint, AssertSpec, DaySpec, EngineOverride, FaultSpec, FleetSpec, HardwareSpec,
    ScenarioPack, SceneSpec, ServingOverride, UpgradeSpec, ASSERT_METRICS,
};
use pd_serve::serving::sim::TransferDiscipline;
use pd_serve::util::prng::Rng;
use pd_serve::util::prop;

fn scenarios_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("scenarios")
}

/// Every committed pack, sorted by file name (deterministic order).
fn discover() -> Vec<PathBuf> {
    let mut packs: Vec<PathBuf> = fs::read_dir(scenarios_dir())
        .expect("scenarios/ directory is committed")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("toml"))
        .collect();
    packs.sort();
    packs
}

#[test]
fn pack_library_is_committed_and_complete() {
    let names: Vec<String> = discover()
        .iter()
        .filter_map(|p| p.file_stem().and_then(|s| s.to_str()).map(str::to_string))
        .collect();
    for required in [
        "chat_heavy",
        "d2d_congestion",
        "example",
        "flash_crowd",
        "mixed_day",
        "mixed_generations",
        "region_failover",
    ] {
        assert!(
            names.iter().any(|n| n == required),
            "pack library lost scenarios/{required}.toml (have: {names:?})"
        );
    }
}

/// The whole gate for one pack: parse, worker-invariance, asserts, golden.
fn gate_pack(path: &Path) {
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("?");
    let pack = ScenarioPack::load(&path.display().to_string())
        .unwrap_or_else(|e| panic!("committed pack failed to parse: {e}"));

    let out = pack.run(1);
    let report = out.to_json();
    let w1 = format!("{}\n", report.to_string_pretty());
    let w4 = format!("{}\n", pack.run(4).to_json().to_string_pretty());
    assert_eq!(
        w1, w4,
        "pack '{name}': --workers 1 and --workers 4 reports differ (sharding oracle broken)"
    );

    let checked = pack
        .check_asserts(&report)
        .unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(checked, pack.asserts.len());

    let golden_path = scenarios_dir().join("goldens").join(format!("{name}.golden.json"));
    let bless = std::env::var("UPDATE_GOLDENS").is_ok();
    match fs::read_to_string(&golden_path) {
        Ok(golden) if golden == w1 => {}
        Ok(golden) if bless => {
            assert_ne!(golden, w1);
            fs::write(&golden_path, &w1).expect("write blessed golden");
            eprintln!("blessed {} — commit it", golden_path.display());
        }
        Ok(golden) => {
            panic!(
                "pack '{name}': {}",
                golden_diff_hint(&golden, &w1, &golden_path.display().to_string())
            );
        }
        Err(_) => {
            // Bootstrap: first run in a fresh build environment writes the
            // snapshot. Commit it — from then on it is a hard gate.
            fs::create_dir_all(golden_path.parent().expect("goldens dir has a parent"))
                .expect("create scenarios/goldens/");
            fs::write(&golden_path, &w1).expect("write bootstrap golden");
            eprintln!(
                "bootstrapped golden {} — commit it to pin this pack",
                golden_path.display()
            );
        }
    }
}

#[test]
fn every_committed_pack_runs_asserts_and_matches_its_golden() {
    let packs = discover();
    assert!(packs.len() >= 5, "pack library shrank: {packs:?}");
    for path in packs {
        gate_pack(&path);
    }
}

#[test]
fn violated_assert_bound_names_the_assertion() {
    // A fast inline day whose assert bound is impossible: the failure
    // must name the pack, the assertion and the actual value — this is
    // the message `pdserve fleet --scenario` prints before exiting 1.
    let text = r#"
name = "doomed"
seed = 9

[day]
hours = 2
peak_rps = 5
ms_per_hour = 250
control_ms = 250

[[scene]]
base = "scene6"

[[assert]]
metric = "completed"
min = 1000000000
"#;
    let pack = ScenarioPack::parse(text).expect("pack itself is valid");
    let report = pack.run(1).to_json();
    let err = pack.check_asserts(&report).expect_err("bound is impossible");
    assert!(
        err.starts_with("pack 'doomed': assert failed: completed >= 1000000000 (actual "),
        "failure must name pack, assertion and actual value, got: {err}"
    );
}

// ---------------------------------------------------------------- property

/// Random in-range pack descriptor (small but schema-covering).
fn arb_pack(r: &mut Rng) -> ScenarioPack {
    let routes = [
        RouteKind::Random,
        RouteKind::RoundRobin,
        RouteKind::LeastLoaded,
        RouteKind::PrefixAffinity,
    ];
    let catalogue = pd_serve::workload::standard_scenarios();
    // Distinct scene bases, 1..=3 of them, in random order.
    let mut idxs: Vec<usize> = (0..catalogue.len()).collect();
    for i in (1..idxs.len()).rev() {
        idxs.swap(i, r.below(i + 1));
    }
    idxs.truncate(1 + r.below(3));
    let scenes = idxs
        .into_iter()
        .map(|base_idx| SceneSpec {
            base: catalogue[base_idx].name.to_string(),
            base_idx,
            weight: (r.below(2) == 0).then(|| r.uniform(0.2, 3.0)),
            prompt_mean: (r.below(2) == 0).then(|| r.uniform(50.0, 4000.0)),
            prompt_cv: (r.below(2) == 0).then(|| r.uniform(0.05, 0.9)),
            gen_mean: (r.below(2) == 0).then(|| r.uniform(8.0, 300.0)),
            gen_cv: (r.below(2) == 0).then(|| r.uniform(0.05, 0.9)),
            prefix_count: (r.below(2) == 0).then(|| 1 + r.below(32)),
            prefix_frac: (r.below(2) == 0).then(|| r.uniform(0.0, 1.0)),
        })
        .collect();
    let min_groups = 1 + r.below(2);
    let n_p = 1 + r.below(3);
    let n_d = 1 + r.below(3);
    let mut asserts = vec![AssertSpec {
        metric: ASSERT_METRICS[r.below(ASSERT_METRICS.len())].to_string(),
        min: Some(r.uniform(0.0, 10.0)),
        max: None,
        eq: None,
        eq_bool: None,
    }];
    if r.below(2) == 0 {
        asserts.push(AssertSpec {
            metric: "ledger.balanced".to_string(),
            min: None,
            max: None,
            eq: None,
            eq_bool: Some(r.below(2) == 0),
        });
    }
    ScenarioPack {
        name: ["alpha", "beta", "gamma", "delta"][r.below(4)].to_string(),
        // Stay in i64 range: TOML integers are signed.
        seed: r.next_u64() >> 1,
        workers: 1 + r.below(4),
        day: DaySpec {
            hours: r.uniform(2.0, 24.0),
            peak_rps: r.uniform(2.0, 40.0),
            ms_per_hour: r.uniform(200.0, 2000.0),
            start_hour: r.uniform(0.0, 23.0),
            control_ms: r.uniform(200.0, 2000.0),
            slice_ms: r.uniform(100.0, 500.0),
        },
        fleet: FleetSpec {
            ratio: (n_p, n_d),
            min_groups,
            max_groups: min_groups + r.below(3),
            spares: r.below(16),
            route: routes[r.below(routes.len())],
            transfer: match r.below(3) {
                0 => TransferDiscipline::Contiguous,
                1 => TransferDiscipline::Blocked,
                _ => TransferDiscipline::Overlapped,
            },
            spray: r.below(2) == 0,
            d2d_response: r.below(2) == 0,
            adjust_ratio: r.below(2) == 0,
            scale_groups: r.below(2) == 0,
            headroom: r.uniform(1.0, 1.6),
            planner: if r.below(2) == 0 { PlannerKind::Capacity } else { PlannerKind::Goodput },
        },
        engine: EngineOverride {
            prefill_per_token_ms: (r.below(2) == 0).then(|| r.uniform(0.05, 0.6)),
            decode_base_ms: (r.below(2) == 0).then(|| r.uniform(5.0, 40.0)),
            batch_efficiency: (r.below(2) == 0).then(|| r.uniform(0.5, 1.0)),
            ..EngineOverride::default()
        },
        serving: ServingOverride {
            ttft_slo_ms_per_1k: (r.below(2) == 0).then(|| r.uniform(300.0, 1200.0)),
            decode_batch: (r.below(2) == 0).then(|| 4 + r.below(28)),
            tpot_slo_ms: (r.below(2) == 0).then(|| r.uniform(50.0, 400.0)),
            ..ServingOverride::default()
        },
        hardware: match r.below(3) {
            // Homogeneous a third of the time; otherwise 2-3 classes.
            0 => Vec::new(),
            n => (0..n + 1)
                .map(|i| HardwareSpec {
                    name: format!("class{i}"),
                    hbm_gb: (r.below(2) == 0).then(|| r.uniform(16.0, 96.0)),
                    cost_per_hour: (r.below(2) == 0).then(|| r.uniform(0.3, 2.0)),
                    engine: EngineOverride {
                        prefill_per_token_ms: (r.below(2) == 0).then(|| r.uniform(0.05, 0.6)),
                        decode_per_row_ms: (r.below(2) == 0).then(|| r.uniform(0.2, 2.0)),
                        ..EngineOverride::default()
                    },
                })
                .collect(),
        },
        scenes,
        faults: FaultSpec {
            per_week: if r.below(2) == 0 { 0.0 } else { r.uniform(1.0, 600.0) },
            detect_ms: r.uniform(1000.0, 8000.0),
        },
        lend: r.below(2) == 0,
        upgrade: (r.below(3) == 0).then(|| UpgradeSpec {
            at_minutes: r.uniform(10.0, 600.0),
            wave: 1 + r.below(2),
        }),
        asserts,
    }
}

#[test]
fn prop_descriptor_roundtrips_through_toml() {
    // serialize → re-parse → equal struct, for every random in-range
    // descriptor. This is what makes `to_toml` a faithful serializer and
    // the pack schema total over its own value space.
    let cfg = prop::Config::default();
    prop::check("scenario-toml-roundtrip", &cfg, arb_pack, |pack| {
        let text = pack.to_toml();
        let back = ScenarioPack::parse(&text)
            .map_err(|e| format!("re-parse failed: {e}\n--- toml ---\n{text}"))?;
        if &back != pack {
            return Err(format!(
                "roundtrip changed the descriptor\n--- toml ---\n{text}\n--- back ---\n{back:#?}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_random_pack_day_is_worker_invariant() {
    // Byte-identical `--json` reports at workers 1 vs 4 for random tiny
    // packs — the sharding oracle holds across the whole descriptor
    // space, not just the committed library. Days are kept tiny (a few
    // virtual seconds) so the case budget stays CI-sized.
    let base = prop::Config::default();
    let cfg = prop::Config { cases: base.cases.min(6), seed: base.seed };
    let tiny = |r: &mut Rng| {
        let mut pack = arb_pack(r);
        pack.day.hours = r.uniform(2.0, 4.0);
        pack.day.ms_per_hour = r.uniform(200.0, 350.0);
        pack.day.control_ms = r.uniform(200.0, 350.0);
        pack.day.slice_ms = 100.0;
        pack.day.peak_rps = r.uniform(2.0, 8.0);
        pack.faults.per_week = if r.below(2) == 0 { 0.0 } else { 400.0 };
        pack
    };
    prop::check("scenario-worker-invariance", &cfg, tiny, |pack| {
        let a = pack.run(1).to_json().to_string_pretty();
        let b = pack.run(4).to_json().to_string_pretty();
        if a == b {
            Ok(())
        } else {
            Err("workers 1 vs 4 reports differ".to_string())
        }
    });
}
