//! Determinism double-run: the scene-sharding contract `pdserve lint`
//! protects, pinned end to end. Two in-process fleet days with the same
//! seed must render byte-identical `--json` reports — not just equal
//! aggregates, but the same bytes: JSON object keys are BTreeMap-sorted,
//! every sort in the control loop carries an id tie-break, and no wall
//! clock or ambient RNG feeds the simulation.

use pd_serve::serving::fleet::{FleetConfig, FleetSim};
use pd_serve::serving::shard::run_sharded;

fn cfg() -> FleetConfig {
    FleetConfig {
        scenes: vec![2, 5],
        peak_total_rps: 24.0,
        hours: 24.0,
        ms_per_hour: 1_500.0,
        control_period_ms: 1_500.0,
        slice_ms: 500.0,
        max_groups_per_scene: 3,
        seed: 0xFA57,
        ..Default::default()
    }
}

#[test]
fn fleet_json_report_is_byte_identical_across_runs() {
    let a = FleetSim::new(cfg()).run().to_json().to_string_pretty();
    let b = FleetSim::new(cfg()).run().to_json().to_string_pretty();
    assert!(!a.is_empty());
    assert_eq!(a, b, "same seed must render byte-identical JSON");
}

#[test]
fn fleet_json_report_has_the_headline_fields() {
    let out = FleetSim::new(cfg()).run();
    let json = out.to_json();
    assert_eq!(json.get("injected").and_then(|v| v.as_usize()), Some(out.injected));
    assert_eq!(json.get("completed").and_then(|v| v.as_usize()), Some(out.completed));
    assert!(json.at(&["ledger", "seed_total"]).is_some());
    let curve = json.get("served_curve").and_then(|v| v.as_arr()).expect("served_curve");
    assert_eq!(curve.len(), out.served_curve.len());
}

#[test]
fn sharded_fleet_json_is_byte_identical_across_worker_counts() {
    // The sharding oracle, end to end: `--workers N` must be a pure
    // performance knob. One worker and four workers render the same
    // bytes, because each scene's day is a pure function of its shard
    // config and the merge runs single-threaded in scene-index order.
    let a = run_sharded(cfg(), 1).to_json().to_string_pretty();
    let b = run_sharded(cfg(), 4).to_json().to_string_pretty();
    assert!(!a.is_empty());
    assert_eq!(a, b, "--workers must not change the report bytes");
}

#[test]
fn sharded_ledger_conserves_instances_for_every_worker_count() {
    // The InstanceLedger invariant survives the merge no matter how the
    // scenes are bucketed onto threads: in-service + banked + pool +
    // scrapped always equals seeded + minted, and the merged report
    // stays balanced.
    for workers in [1usize, 2, 3, 5] {
        let out = run_sharded(cfg(), workers);
        let l = &out.ledger;
        assert_eq!(
            l.in_service + l.banked + l.pool + l.scrapped,
            l.seed_total + l.minted,
            "ledger leaks instances at workers={workers}"
        );
        assert!(l.balanced, "merged ledger unbalanced at workers={workers}");
    }
}

#[test]
fn scenario_pack_day_is_byte_identical_across_runs_and_worker_counts() {
    // The scenario path inherits the whole contract: a pack compiled
    // through `ScenarioPack::compile` runs on `run_sharded`, so two runs
    // of the same pack — and any two worker counts — must render the
    // same report bytes. Mirrors `cfg()` above, expressed as a pack.
    use pd_serve::serving::scenario::ScenarioPack;
    let text = r#"
name = "determinism"
seed = 64087

[day]
hours = 24
peak_rps = 24
ms_per_hour = 1500
control_ms = 1500
slice_ms = 500

[fleet]
max_groups = 3

[[scene]]
base = "scene3"

[[scene]]
base = "scene6"
"#;
    let pack = ScenarioPack::parse(text).expect("inline pack parses");
    let a = pack.run(1).to_json().to_string_pretty();
    let b = pack.run(1).to_json().to_string_pretty();
    let c = pack.run(4).to_json().to_string_pretty();
    assert!(!a.is_empty());
    assert_eq!(a, b, "same pack must render byte-identical JSON across runs");
    assert_eq!(a, c, "--workers must not change a scenario pack's report bytes");
}

#[test]
fn overlapped_day_is_byte_identical_across_worker_counts() {
    // The layer-wise pipelined discipline adds per-window exposed-tail
    // accounting and a congestion latch to the control loop; both must
    // stay pure functions of the shard config, so an overlapped day with
    // the d2d_util response armed renders the same bytes at any width.
    let base = FleetConfig {
        transfer: pd_serve::serving::sim::TransferDiscipline::Overlapped,
        d2d_response: true,
        ..cfg()
    };
    let a = run_sharded(base.clone(), 1).to_json().to_string_pretty();
    let b = run_sharded(base.clone(), 4).to_json().to_string_pretty();
    assert!(!a.is_empty());
    assert_eq!(a, b, "--workers must not change the overlapped report bytes");
    // And the overlapped day is genuinely a different day: the exposed
    // tail lands in TTFT, so the report differs from the contiguous one.
    let contiguous = run_sharded(cfg(), 1).to_json().to_string_pretty();
    assert_ne!(a, contiguous, "transfer discipline must influence the report");
}

#[test]
fn different_seeds_actually_differ() {
    // Guards the double-run test against vacuous passes (e.g. a to_json
    // that ignores the simulation entirely).
    let a = FleetSim::new(cfg()).run().to_json().to_string_pretty();
    let other = FleetConfig { seed: 0x5EED, ..cfg() };
    let b = FleetSim::new(other).run().to_json().to_string_pretty();
    assert_ne!(a, b, "seed must influence the report");
}
