//! Determinism double-run: the scene-sharding contract `pdserve lint`
//! protects, pinned end to end. Two in-process fleet days with the same
//! seed must render byte-identical `--json` reports — not just equal
//! aggregates, but the same bytes: JSON object keys are BTreeMap-sorted,
//! every sort in the control loop carries an id tie-break, and no wall
//! clock or ambient RNG feeds the simulation.

use pd_serve::serving::fleet::{FleetConfig, FleetSim};

fn cfg() -> FleetConfig {
    FleetConfig {
        scenes: vec![2, 5],
        peak_total_rps: 24.0,
        hours: 24.0,
        ms_per_hour: 1_500.0,
        control_period_ms: 1_500.0,
        slice_ms: 500.0,
        max_groups_per_scene: 3,
        seed: 0xFA57,
        ..Default::default()
    }
}

#[test]
fn fleet_json_report_is_byte_identical_across_runs() {
    let a = FleetSim::new(cfg()).run().to_json().to_string_pretty();
    let b = FleetSim::new(cfg()).run().to_json().to_string_pretty();
    assert!(!a.is_empty());
    assert_eq!(a, b, "same seed must render byte-identical JSON");
}

#[test]
fn fleet_json_report_has_the_headline_fields() {
    let out = FleetSim::new(cfg()).run();
    let json = out.to_json();
    assert_eq!(json.get("injected").and_then(|v| v.as_usize()), Some(out.injected));
    assert_eq!(json.get("completed").and_then(|v| v.as_usize()), Some(out.completed));
    assert!(json.at(&["ledger", "seed_total"]).is_some());
    let curve = json.get("served_curve").and_then(|v| v.as_arr()).expect("served_curve");
    assert_eq!(curve.len(), out.served_curve.len());
}

#[test]
fn different_seeds_actually_differ() {
    // Guards the double-run test against vacuous passes (e.g. a to_json
    // that ignores the simulation entirely).
    let a = FleetSim::new(cfg()).run().to_json().to_string_pretty();
    let other = FleetConfig { seed: 0x5EED, ..cfg() };
    let b = FleetSim::new(other).run().to_json().to_string_pretty();
    assert_ne!(a, b, "seed must influence the report");
}
