//! Integration: the real serving engine end-to-end — gateway policy +
//! PJRT prefill/decode + byte transfer + operator RecvScatter under
//! continuous batching, with python nowhere on the path.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use pd_serve::serving::server::{RealEngine, RealRequest};

fn artifacts_dir() -> Option<&'static str> {
    ["artifacts", "../artifacts"]
        .into_iter()
        .find(|d| std::path::Path::new(&format!("{d}/meta.json")).exists())
}

#[test]
fn serves_batch_to_completion() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let mut engine = RealEngine::new(dir, 2, 2).unwrap();
    let requests: Vec<RealRequest> = (0..10)
        .map(|i| RealRequest {
            id: i,
            prompt: format!("request number {i} asks for tokens"),
            max_new_tokens: 8,
        })
        .collect();
    let report = engine.serve(&requests).unwrap();
    assert_eq!(report.outcomes.len(), 10, "every request completes");
    for o in &report.outcomes {
        assert!(o.gen_tokens >= 1 && o.gen_tokens <= 32);
        assert!(o.ttft_ms > 0.0);
        assert!(o.e2e_ms >= o.ttft_ms);
        assert!(!o.output.is_empty());
    }
    assert!(report.prefill_execs == 10);
    assert!(report.decode_iters > 0);
    // Continuous batching actually batched: fewer iterations than a
    // serial execution would need (10 requests x 8 tokens = 80 serial).
    assert!(
        report.decode_iters < 60,
        "expected batched decoding, got {} iters",
        report.decode_iters
    );
}

#[test]
fn deterministic_outputs_across_runs() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let run = || {
        let mut engine = RealEngine::new(dir, 1, 1).unwrap();
        let requests = vec![RealRequest {
            id: 0,
            prompt: "determinism check".into(),
            max_new_tokens: 6,
        }];
        let report = engine.serve(&requests).unwrap();
        report.outcomes[0].output.clone()
    };
    assert_eq!(run(), run(), "greedy decoding must be deterministic");
}

#[test]
fn respects_generation_budget_and_max_len() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let mut engine = RealEngine::new(dir, 1, 1).unwrap();
    let max_len = engine.meta().max_len;
    let bucket = *engine.meta().prefill_buckets.last().unwrap();
    // Ask for far more tokens than the cache can hold.
    let requests = vec![RealRequest {
        id: 0,
        prompt: "x".repeat(bucket),
        max_new_tokens: 10_000,
    }];
    let report = engine.serve(&requests).unwrap();
    let o = &report.outcomes[0];
    assert!(
        bucket + o.gen_tokens <= max_len,
        "generated past the cache: {} + {} > {max_len}",
        bucket,
        o.gen_tokens
    );
}
