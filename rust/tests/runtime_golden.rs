//! Integration: replay `artifacts/golden.json` (recorded by the python AOT
//! path) through the rust PJRT runtime. This closes the cross-language
//! loop — if the HLO text round-trip, the literal plumbing, the scatter
//! path or the decode loop were wrong, tokens would diverge immediately.
//!
//! Requires `make artifacts` to have run (skips with a message otherwise).

use pd_serve::runtime::model::{bytemuck_cast, bytes_as_f32};
use pd_serve::runtime::ServingRuntime;
use pd_serve::util::json::Json;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts", "../../artifacts"] {
        if std::path::Path::new(&format!("{dir}/meta.json")).exists() {
            return Some(dir.to_string());
        }
    }
    None
}

struct Golden {
    prompt: Vec<i32>,
    nnew: usize,
    first_token: i32,
    generated: Vec<i32>,
    prefill_logits_head: Vec<f64>,
    final_logits_head: Vec<f64>,
    prefill_cache_mean: f64,
    prefill_cache_std: f64,
}

fn load_golden(dir: &str) -> Golden {
    let text = std::fs::read_to_string(format!("{dir}/golden.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    Golden {
        prompt: j
            .get("prompt")
            .and_then(Json::as_usize_vec)
            .unwrap()
            .into_iter()
            .map(|x| x as i32)
            .collect(),
        nnew: j.get("nnew").and_then(Json::as_usize).unwrap(),
        first_token: j.get("first_token").and_then(Json::as_i64).unwrap() as i32,
        generated: j
            .get("generated")
            .and_then(Json::as_usize_vec)
            .unwrap()
            .into_iter()
            .map(|x| x as i32)
            .collect(),
        prefill_logits_head: j
            .get("prefill_logits_head")
            .and_then(Json::as_f64_vec)
            .unwrap(),
        final_logits_head: j
            .get("final_logits_head")
            .and_then(Json::as_f64_vec)
            .unwrap(),
        prefill_cache_mean: j.get("prefill_cache_mean").and_then(Json::as_f64).unwrap(),
        prefill_cache_std: j.get("prefill_cache_std").and_then(Json::as_f64).unwrap(),
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn golden_replay_matches_python() {
    let dir = require_artifacts!();
    let golden = load_golden(&dir);
    let rt = ServingRuntime::load(&dir).unwrap();

    // --- prefill ---
    let out = rt.prefill(&golden.prompt, 0, None).unwrap();
    assert_eq!(golden.nnew, golden.prompt.len());
    assert_eq!(out.logits.len(), rt.meta.vocab);
    for (i, &exp) in golden.prefill_logits_head.iter().enumerate() {
        assert!(
            (out.logits[i] as f64 - exp).abs() < 2e-3,
            "prefill logit {i}: rust={} python={exp}",
            out.logits[i]
        );
    }
    let first = rt.argmax_row(&out.logits, 0);
    assert_eq!(first, golden.first_token, "first generated token differs");

    // Cache statistics sanity (full-tensor comparison happens implicitly
    // through the decode trace below).
    let n = out.cache.len() as f64;
    let mean = out.cache.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = out.cache.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
    assert!((mean - golden.prefill_cache_mean).abs() < 1e-4, "cache mean");
    assert!((var.sqrt() - golden.prefill_cache_std).abs() < 1e-4, "cache std");

    // --- transfer: contiguous bytes -> operator RecvScatter into slot 0 ---
    let bytes = bytemuck_cast(&out.cache).to_vec(); // "the wire"
    let restored = bytes_as_f32(&bytes);
    let mut handle = rt.new_decode_handle().unwrap();
    rt.scatter_device(&mut handle, 0, &restored).unwrap();
    handle.lens[0] = golden.nnew as i32;
    handle.active[0] = true;

    // --- decode trace: every token must match the python replay exactly ---
    let b = handle.batch();
    let mut tok = vec![0i32; b];
    tok[0] = first;
    let mut produced = vec![first];
    let mut last_logits = Vec::new();
    for _ in 0..(golden.generated.len() - 1) {
        let logits = rt.decode_step(&mut handle, &tok).unwrap();
        let nxt = rt.argmax_row(&logits, 0);
        produced.push(nxt);
        last_logits = logits[..rt.meta.vocab].to_vec();
        tok[0] = nxt;
    }
    assert_eq!(produced, golden.generated, "token trace diverged");
    for (i, &exp) in golden.final_logits_head.iter().enumerate() {
        assert!(
            (last_logits[i] as f64 - exp).abs() < 2e-3,
            "final logit {i}: rust={} python={exp}",
            last_logits[i]
        );
    }
}

#[test]
fn scatter_device_and_host_paths_agree() {
    // The paper's §3.6 transparency/flexibility tradeoff: the *operator*
    // RecvScatter (AOT HLO) and the *function* RecvScatter (host byte
    // scatter in kvcache::scatter) must land identical caches.
    let dir = require_artifacts!();
    let rt = ServingRuntime::load(&dir).unwrap();
    let prompt = pd_serve::runtime::tokenizer::encode("scatter equivalence");
    let out = rt.prefill(&prompt, 0, None).unwrap();

    let slot = 2usize;
    // Operator path.
    let mut h_dev = rt.new_decode_handle().unwrap();
    rt.scatter_device(&mut h_dev, slot, &out.cache).unwrap();
    let dev_cache = h_dev.cache_to_vec().unwrap();

    // Function path (host mirror scatter).
    let mut h_host = rt.new_decode_handle().unwrap();
    let mut mirror = h_host.cache_to_vec().unwrap();
    pd_serve::kvcache::scatter::scatter_into_decode(
        &mut mirror,
        &out.cache,
        &rt.meta.decode_cache_shape,
        slot,
    )
    .unwrap();
    h_host
        .cache_from_vec(&mirror, &rt.meta.decode_cache_shape)
        .unwrap();
    let host_cache = h_host.cache_to_vec().unwrap();

    assert_eq!(dev_cache.len(), host_cache.len());
    let diff = dev_cache
        .iter()
        .zip(&host_cache)
        .filter(|(a, b)| a != b)
        .count();
    assert_eq!(diff, 0, "{diff} elements differ between scatter paths");
}

#[test]
fn prefix_continuation_matches_single_shot() {
    // Chunked prefill over a cached prefix (start > 0) must produce the
    // same logits as prefilling the whole prompt at once — the correctness
    // property behind prefix-aware KVCache reuse.
    let dir = require_artifacts!();
    let rt = ServingRuntime::load(&dir).unwrap();
    let full: Vec<i32> = (0..32).map(|i| (i * 7 + 3) % 256).collect();

    let single = rt.prefill(&full, 0, None).unwrap();

    let chunk1 = rt.prefill(&full[..16], 0, None).unwrap();
    let chunk2 = rt.prefill(&full[16..], 16, Some(&chunk1.cache)).unwrap();

    let max_diff = single
        .logits
        .iter()
        .zip(&chunk2.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "chunked vs single-shot logits diff {max_diff}");
}

#[test]
fn decode_slots_are_isolated() {
    // Continuous batching invariant: activity in other slots must not
    // change an active slot's token stream.
    let dir = require_artifacts!();
    let rt = ServingRuntime::load(&dir).unwrap();
    let prompt = pd_serve::runtime::tokenizer::encode("slot isolation");

    let out = rt.prefill(&prompt, 0, None).unwrap();
    let run = |other_tok: i32, other_active: bool| {
        let mut h = rt.new_decode_handle().unwrap();
        rt.scatter_device(&mut h, 0, &out.cache).unwrap();
        h.lens[0] = prompt.len() as i32;
        h.active[0] = true;
        if other_active {
            h.lens[1] = 3;
            h.active[1] = true;
        }
        let mut tok = vec![0i32; h.batch()];
        tok[0] = rt.argmax_row(&out.logits, 0);
        tok[1] = other_tok;
        let mut trace = Vec::new();
        for _ in 0..4 {
            let logits = rt.decode_step(&mut h, &tok).unwrap();
            let nxt = rt.argmax_row(&logits, 0);
            trace.push(nxt);
            tok[0] = nxt;
        }
        trace
    };
    assert_eq!(run(0, false), run(99, true));
}
