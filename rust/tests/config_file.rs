//! The shipped config file must parse into every typed config without
//! falling back to defaults silently.

use pd_serve::util::config::{ClusterConfig, Doc, EngineConfig, ServingConfig};

fn load() -> Doc {
    let path = ["configs/default.toml", "../configs/default.toml"]
        .into_iter()
        .find(|p| std::path::Path::new(p).exists())
        .expect("configs/default.toml present");
    Doc::load(path).expect("parses")
}

#[test]
fn default_config_parses_fully() {
    let doc = load();
    assert_eq!(doc.str_or("", "name", "?"), "pd-serve-default");

    let cluster = ClusterConfig::from_doc(&doc);
    assert_eq!(cluster.regions, 2);
    assert_eq!(cluster.total_devices(), 2 * 8 * 4 * 8);
    assert_eq!(cluster.spine_count, 8);

    let engine = EngineConfig::from_doc(&doc);
    assert!((engine.prefill_per_token_ms - 0.30).abs() < 1e-12);
    assert!((engine.prefill_quad_ms - 1e-5).abs() < 1e-12);

    let serving = ServingConfig::from_doc(&doc);
    assert_eq!(serving.prefill_batch, 4);
    assert_eq!(serving.decode_batch, 16);
    assert!((serving.ttft_threshold_ms(1024) - 600.0).abs() < 1e-9);
}

#[test]
fn config_values_differ_from_defaults_where_specified() {
    // Guards against the parser silently ignoring the file: spine_count is
    // 8 in the file but 4 in the built-in default.
    let doc = load();
    let cluster = ClusterConfig::from_doc(&doc);
    assert_ne!(cluster.spine_count, ClusterConfig::default().spine_count);
}
